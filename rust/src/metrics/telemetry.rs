//! Live per-rank telemetry: a sampler thread that periodically snapshots
//! every metrics family, keeps a bounded flight-recorder ring of
//! timestamped samples, and publishes the latest one through the gang's
//! kv store so an external observer (`bench_driver top`) can watch a
//! running pipeline (DESIGN.md §14).
//!
//! Off by default (`CYLONFLOW_TELEMETRY` /
//! [`crate::config::TelemetryConfig`]): when disabled,
//! [`TelemetryPublisher::maybe_start`] returns `None` — no thread is
//! spawned, no counter is touched, results stay byte-identical
//! (pinned by `tests/telemetry.rs`). When enabled, each sample is also
//! appended eagerly (write + flush per line) to a flight-recorder JSONL
//! file, so a SIGKILLed rank still leaves its last observed state on
//! disk for the fault-leg artifacts.

use super::{json, MetricsSnapshot, StatsHub};
use crate::comm::{Communicator, KvStore};
use crate::config::TelemetryConfig;
use crate::executor::MorselPool;
use crate::trace::TraceSink;
use std::collections::VecDeque;
use std::io::Write;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

/// Samples the flight-recorder ring retains per rank (oldest evicted
/// beyond it). At the default 200 ms interval this is ~100 s of history.
pub const TELEMETRY_RING_CAP: usize = 512;

/// Everything a sampler needs to assemble one rank's unified
/// [`MetricsSnapshot`]: the worker-side and comm-side [`StatsHub`]s, the
/// transport (for `bytes_sent`), the trace sink (for its event
/// counters) and the morsel pool (for `local_*` and its busy-time
/// histogram). Cheap to clone — all `Arc`s.
///
/// [`crate::executor::CylonEnv::snapshot`] builds its snapshot through
/// the same source, so what the sampler thread publishes is exactly what
/// the worker itself would report at that instant.
#[derive(Clone)]
pub struct TelemetrySource {
    env: Arc<StatsHub>,
    comm: Arc<StatsHub>,
    transport: Arc<dyn Communicator>,
    trace: Arc<TraceSink>,
    pool: Arc<MorselPool>,
}

impl std::fmt::Debug for TelemetrySource {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TelemetrySource")
            .field("rank", &self.transport.rank())
            .finish_non_exhaustive()
    }
}

impl TelemetrySource {
    /// Bundle one rank's stat holders into a sampling source.
    pub fn new(
        env: Arc<StatsHub>,
        comm: Arc<StatsHub>,
        transport: Arc<dyn Communicator>,
        trace: Arc<TraceSink>,
        pool: Arc<MorselPool>,
    ) -> TelemetrySource {
        TelemetrySource { env, comm, transport, trace, pool }
    }

    /// One rank's unified metrics view right now: worker + comm timers
    /// merged, every family read from its owning hub, histograms the
    /// union of the worker, comm and pool seams, and the named-counter
    /// registry extended with the transport/trace built-ins
    /// (`bytes_sent`, `trace_events_dropped`, `trace_events_recorded`),
    /// sorted by name.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut timers = self.env.peek_timers();
        timers.merge(&self.comm.peek_timers());
        let mut hists = self.env.peek_hists();
        hists.merge(&self.comm.peek_hists());
        hists.merge(&self.pool.hists());
        let mut counters = self.env.counters();
        counters.push(("bytes_sent".to_string(), self.transport.bytes_sent()));
        counters.push(("trace_events_dropped".to_string(), self.trace.overflow_count()));
        counters.push(("trace_events_recorded".to_string(), self.trace.recorded_count()));
        counters.sort();
        MetricsSnapshot {
            timers,
            spill: self.comm.peek_spill(),
            skew: self.env.peek_skew(),
            overlap: self.comm.peek_overlap(),
            local: self.pool.stats(),
            counters,
            hists,
        }
    }

    /// The stage label the worker most recently published ("" before the
    /// first stage).
    pub fn current_stage(&self) -> String {
        self.env.current_stage()
    }
}

/// One timestamped telemetry observation: the cumulative snapshot plus
/// the delta since the previous sample (what rate displays divide by the
/// sampling interval). JSON round-trippable — the flight recorder writes
/// [`TelemetrySample::to_json`] lines and `bench_driver top` reads them
/// back with [`TelemetrySample::from_json`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TelemetrySample {
    /// Publishing rank.
    pub rank: usize,
    /// Elastic generation the rank is executing (0 outside elastic runs).
    pub generation: u64,
    /// Monotonic per-publisher sequence number, from 1.
    pub seq: u64,
    /// Wall-clock capture time, milliseconds since the Unix epoch.
    pub unix_ms: u64,
    /// Milliseconds since the publisher started (monotonic clock).
    pub elapsed_ms: u64,
    /// Stage label the worker was in when sampled ("" between stages).
    pub stage: String,
    /// Cumulative snapshot at capture time.
    pub total: MetricsSnapshot,
    /// `total − previous sample's total` (family-wise
    /// [`MetricsSnapshot::saturating_diff`]); the first sample's delta
    /// equals its total.
    pub delta: MetricsSnapshot,
}

fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

impl TelemetrySample {
    /// One-line JSON object (nested snapshots via
    /// [`MetricsSnapshot::to_json`]) — the flight-recorder JSONL line and
    /// the kv-published value.
    pub fn to_json(&self) -> String {
        format!(
            concat!(
                "{{\"rank\": {}, \"generation\": {}, \"seq\": {}, ",
                "\"unix_ms\": {}, \"elapsed_ms\": {}, \"stage\": \"{}\", ",
                "\"total\": {}, \"delta\": {}}}"
            ),
            self.rank,
            self.generation,
            self.seq,
            self.unix_ms,
            self.elapsed_ms,
            escape(&self.stage),
            self.total.to_json(),
            self.delta.to_json(),
        )
    }

    /// Parse a sample back from [`TelemetrySample::to_json`]'s output
    /// (`from_json(to_json(s)) == s`). Missing fields read as 0/""/empty,
    /// so truncated-but-parseable flight lines still yield data.
    ///
    /// # Errors
    /// [`crate::error::Error::InvalidArgument`] on structurally malformed
    /// input (a torn final flight line after SIGKILL, for example).
    pub fn from_json(text: &str) -> crate::error::Result<TelemetrySample> {
        let invalid = |e: String| crate::error::Error::invalid(format!("telemetry json: {e}"));
        let obj = json::parse_object(text).map_err(invalid)?;
        let snap = |key: &str| -> Result<MetricsSnapshot, String> {
            match obj.field(key) {
                Some(v) => MetricsSnapshot::from_parsed(v),
                None => Ok(MetricsSnapshot::default()),
            }
        };
        Ok(TelemetrySample {
            rank: obj.num("rank").map_err(invalid)? as usize,
            generation: obj.num("generation").map_err(invalid)?,
            seq: obj.num("seq").map_err(invalid)?,
            unix_ms: obj.num("unix_ms").map_err(invalid)?,
            elapsed_ms: obj.num("elapsed_ms").map_err(invalid)?,
            stage: obj.str_field("stage").map_err(invalid)?,
            total: snap("total").map_err(invalid)?,
            delta: snap("delta").map_err(invalid)?,
        })
    }
}

/// Where a publisher sends its samples: optionally a kv key (the gang's
/// `{gang}/telemetry/g{gen}/{rank}` — latest sample wins, atomic via the
/// [`crate::comm::FileKv`] tmp+rename put) and optionally a
/// flight-recorder JSONL path (every sample appended and flushed, so the
/// file survives SIGKILL mid-run). Both best-effort: a full disk or torn
/// kv dir must never take the worker down, so publish errors are counted,
/// not raised.
#[derive(Debug, Default)]
pub struct TelemetrySink {
    kv: Option<(Arc<dyn KvStore>, String)>,
    flight: Option<PathBuf>,
}

impl TelemetrySink {
    /// A sink that publishes nowhere (samples still land in the ring).
    pub fn new() -> TelemetrySink {
        TelemetrySink::default()
    }

    /// Also publish the latest sample under `key` in `kv`.
    pub fn with_kv(mut self, kv: Arc<dyn KvStore>, key: impl Into<String>) -> TelemetrySink {
        self.kv = Some((kv, key.into()));
        self
    }

    /// Also append every sample as one JSONL line to `path`.
    pub fn with_flight(mut self, path: impl Into<PathBuf>) -> TelemetrySink {
        self.flight = Some(path.into());
        self
    }

    /// Publish one sample; returns how many destinations failed.
    fn publish(&self, sample: &TelemetrySample) -> u64 {
        let line = sample.to_json();
        let mut failures = 0;
        if let Some((kv, key)) = &self.kv {
            if kv.put(key, line.as_bytes()).is_err() {
                failures += 1;
            }
        }
        if let Some(path) = &self.flight {
            let open = || std::fs::OpenOptions::new().create(true).append(true).open(path);
            // The flight path usually lives in a not-yet-created
            // subdirectory (`{kv_dir}/flight/`); materialize it on the
            // first append — here rather than in `with_flight`, so a
            // sink built for a publisher that never starts (telemetry
            // disabled) touches no disk at all.
            let appended = open()
                .or_else(|e| match path.parent() {
                    Some(parent) => {
                        std::fs::create_dir_all(parent)?;
                        open()
                    }
                    None => Err(e),
                })
                .and_then(|mut f| {
                    f.write_all(line.as_bytes())?;
                    f.write_all(b"\n")?;
                    f.flush()
                });
            if appended.is_err() {
                failures += 1;
            }
        }
        failures
    }
}

impl std::fmt::Debug for TelemetryPublisher {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TelemetryPublisher")
            .field("samples", &self.ring.lock().map(|r| r.len()).unwrap_or(0))
            .finish_non_exhaustive()
    }
}

/// The per-rank sampler thread: every `CYLONFLOW_TELEMETRY_MS` it
/// captures a [`TelemetrySample`] (cumulative snapshot + delta since the
/// last sample), appends it to the bounded flight-recorder ring and
/// hands it to the [`TelemetrySink`]. The thread follows the
/// heartbeat idiom from [`crate::executor::elastic`]: named, sliced
/// 2 ms sleeps for prompt shutdown, stopped + joined on `Drop`. A final
/// sample is always captured at stop, so even a pipeline shorter than
/// one interval publishes its end state.
pub struct TelemetryPublisher {
    stop: Arc<AtomicBool>,
    ring: Arc<Mutex<VecDeque<TelemetrySample>>>,
    handle: Option<JoinHandle<()>>,
}

impl TelemetryPublisher {
    /// Start the sampler if `cfg.enabled`; `None` otherwise — the
    /// disabled path spawns no thread and touches nothing (what the
    /// disabled-path test pins). `generation` tags every sample (0 for
    /// non-elastic runs).
    pub fn maybe_start(
        cfg: &TelemetryConfig,
        generation: u64,
        source: TelemetrySource,
        sink: TelemetrySink,
    ) -> Option<TelemetryPublisher> {
        if !cfg.enabled {
            return None;
        }
        let period = cfg.interval();
        let rank = source.transport.rank();
        let stop = Arc::new(AtomicBool::new(false));
        let ring = Arc::new(Mutex::new(VecDeque::new()));
        let thread_stop = Arc::clone(&stop);
        let thread_ring = Arc::clone(&ring);
        let handle = std::thread::Builder::new()
            .name(format!("cyf-telemetry-{rank}"))
            .spawn(move || {
                let started = Instant::now();
                let mut prev = MetricsSnapshot::default();
                let mut seq = 0u64;
                let mut capture = |prev: &mut MetricsSnapshot, seq: &mut u64| {
                    let total = source.snapshot();
                    let delta = total.saturating_diff(prev);
                    *prev = total.clone();
                    *seq += 1;
                    let sample = TelemetrySample {
                        rank,
                        generation,
                        seq: *seq,
                        unix_ms: unix_ms(),
                        elapsed_ms: started.elapsed().as_millis() as u64,
                        stage: source.current_stage(),
                        total,
                        delta,
                    };
                    sink.publish(&sample);
                    let mut ring = thread_ring.lock().expect("telemetry ring poisoned");
                    if ring.len() >= TELEMETRY_RING_CAP {
                        ring.pop_front();
                    }
                    ring.push_back(sample);
                };
                loop {
                    let mut slept = Duration::ZERO;
                    while slept < period && !thread_stop.load(Ordering::Relaxed) {
                        let slice = (period - slept).min(Duration::from_millis(2));
                        std::thread::sleep(slice);
                        slept += slice;
                    }
                    if thread_stop.load(Ordering::Relaxed) {
                        break;
                    }
                    capture(&mut prev, &mut seq);
                }
                // end-of-run state, even for sub-interval pipelines
                capture(&mut prev, &mut seq);
            })
            .expect("spawn telemetry thread");
        Some(TelemetryPublisher { stop, ring, handle: Some(handle) })
    }

    /// The flight-recorder ring: up to [`TELEMETRY_RING_CAP`] most recent
    /// samples, oldest first.
    pub fn samples(&self) -> Vec<TelemetrySample> {
        self.ring.lock().expect("telemetry ring poisoned").iter().cloned().collect()
    }

    /// Stop and join the sampler (also captures the final sample). Idempotent;
    /// `Drop` calls it too.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for TelemetryPublisher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn unix_ms() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default().as_millis() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{InMemoryKv, MemoryFabric};
    use crate::metrics::Phase;

    fn one_rank_source() -> (TelemetrySource, Arc<StatsHub>, Arc<StatsHub>) {
        let env = Arc::new(StatsHub::new());
        let comm = Arc::new(StatsHub::new());
        let transport: Arc<dyn Communicator> = Arc::new(MemoryFabric::create(1).remove(0));
        let source = TelemetrySource::new(
            Arc::clone(&env),
            Arc::clone(&comm),
            transport,
            TraceSink::disabled(),
            MorselPool::disabled(),
        );
        (source, env, comm)
    }

    #[test]
    fn source_snapshot_unifies_both_hubs() {
        let (source, env, comm) = one_rank_source();
        env.add_phase(Phase::Compute, Duration::from_nanos(300));
        env.bump_counter("rows_out", 9);
        env.record_hist("stage_duration_ns", 1000);
        env.set_stage("join");
        comm.add_phase(Phase::Communication, Duration::from_nanos(700));
        comm.record_spill(crate::metrics::SpillStats { spilled_bytes: 64, spill_count: 1 });
        comm.record_hist("collective_ns", 500);
        let s = source.snapshot();
        assert_eq!(s.timers.get(Phase::Compute), Duration::from_nanos(300));
        assert_eq!(s.timers.get(Phase::Communication), Duration::from_nanos(700));
        assert_eq!(s.spill.spilled_bytes, 64);
        assert_eq!(s.counter("rows_out"), 9);
        assert!(s.hists.get("stage_duration_ns").is_some());
        assert!(s.hists.get("collective_ns").is_some());
        // transport/trace built-ins are always present
        assert!(s.counters.iter().any(|(n, _)| n == "bytes_sent"));
        assert!(s.counters.iter().any(|(n, _)| n == "trace_events_recorded"));
        // sorted by name for deterministic JSON
        let names: Vec<&str> = s.counters.iter().map(|(n, _)| n.as_str()).collect();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
        assert_eq!(source.current_stage(), "join");
    }

    #[test]
    fn sample_json_round_trips() {
        let (source, env, _comm) = one_rank_source();
        env.bump_counter("rows_out", 3);
        env.record_hist("stage_duration_ns", 12345);
        let total = source.snapshot();
        let sample = TelemetrySample {
            rank: 1,
            generation: 2,
            seq: 7,
            unix_ms: 1_700_000_000_123,
            elapsed_ms: 456,
            stage: "join(replayed)".into(),
            total: total.clone(),
            delta: total,
        };
        let back = TelemetrySample::from_json(&sample.to_json()).unwrap();
        assert_eq!(back, sample);
        // a torn flight line (SIGKILL mid-write) errors, never panics
        let line = sample.to_json();
        assert!(TelemetrySample::from_json(&line[..line.len() - 5]).is_err());
    }

    #[test]
    fn disabled_config_spawns_nothing() {
        let (source, _env, _comm) = one_rank_source();
        let cfg = TelemetryConfig::default();
        assert!(!cfg.enabled, "telemetry must be opt-in");
        assert!(TelemetryPublisher::maybe_start(&cfg, 0, source, TelemetrySink::new()).is_none());
    }

    #[test]
    fn publisher_samples_ring_kv_and_flight() {
        let (source, env, _comm) = one_rank_source();
        let kv: Arc<dyn KvStore> = InMemoryKv::shared();
        let dir = std::env::temp_dir().join(format!("cyf-telemetry-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let flight = dir.join("rank0.flight.jsonl");
        let cfg = TelemetryConfig { enabled: true, interval_ms: 5 };
        let sink =
            TelemetrySink::new().with_kv(Arc::clone(&kv), "g/telemetry/g0/0").with_flight(&flight);
        let mut publisher =
            TelemetryPublisher::maybe_start(&cfg, 0, source, sink).expect("enabled");
        env.bump_counter("rows_out", 42);
        std::thread::sleep(Duration::from_millis(40));
        publisher.shutdown();
        let samples = publisher.samples();
        assert!(!samples.is_empty(), "sampler must have fired");
        // seq strictly increasing from 1; the counter bump was observed
        for (i, s) in samples.iter().enumerate() {
            assert_eq!(s.seq, i as u64 + 1);
            assert_eq!(s.rank, 0);
        }
        assert_eq!(samples.last().unwrap().total.counter("rows_out"), 42);
        // deltas reconstruct the totals: sum of deltas == final total
        let mut acc = MetricsSnapshot::default();
        for s in &samples {
            acc.merge(&s.delta);
        }
        assert_eq!(acc.counter("rows_out"), 42);
        // kv holds the latest sample
        let latest = kv.wait("g/telemetry/g0/0", Duration::from_secs(1)).unwrap();
        let latest = TelemetrySample::from_json(std::str::from_utf8(&latest).unwrap()).unwrap();
        assert_eq!(latest.seq, samples.last().unwrap().seq);
        // flight file holds every sample as parseable JSONL
        let text = std::fs::read_to_string(&flight).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), samples.len());
        for line in &lines {
            TelemetrySample::from_json(line).unwrap();
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

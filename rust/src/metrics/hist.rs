//! Log2-bucketed histograms — the latency/size distribution layer of the
//! observability plane (DESIGN.md §14).
//!
//! Counters tell you *how much*; histograms tell you *how it was
//! distributed*. A [`Histogram`] buckets `u64` observations (nanoseconds,
//! bytes) by bit length, so the whole distribution is 65 integers —
//! cheap enough to record on hot paths, merge across ranks, diff across
//! stage boundaries, and ship through the same monotonic
//! snapshot-and-diff discipline every other metrics family uses
//! ([`crate::metrics::MetricsSnapshot::saturating_diff`]). Quantile
//! readouts ([`Histogram::quantile`], p50/p95/p99) resolve to the upper
//! bound of the containing bucket, i.e. they are exact to within the 2×
//! bucket width — the right fidelity for "is p99 a millisecond or a
//! second", which is what the adaptive optimizer and the `bench_driver
//! top` view consume.
//!
//! [`HistSet`] is the named registry: a `BTreeMap` keyed by stable seam
//! names (`stage_duration_ns`, `collective_ns`, `spill_write_bytes`, …)
//! with set-wise merge/diff, carried inside
//! [`crate::metrics::StageTiming`] and [`crate::metrics::MetricsSnapshot`].

use std::collections::BTreeMap;

/// Bucket count: index 0 holds the value 0, index `i ∈ 1..=64` holds
/// values of bit length `i` (range `[2^(i-1), 2^i)`).
pub const HIST_BUCKETS: usize = 65;

/// A log2-bucketed distribution of `u64` observations. Monotonic like
/// every other metrics family: it only ever accumulates, and stage/window
/// attribution happens by [`Histogram::saturating_diff`] between two
/// snapshots of the same histogram.
#[derive(Clone, PartialEq, Eq)]
pub struct Histogram {
    buckets: [u64; HIST_BUCKETS],
    count: u64,
    sum: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { buckets: [0; HIST_BUCKETS], count: 0, sum: 0 }
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Histogram")
            .field("count", &self.count)
            .field("sum", &self.sum)
            .field("p50", &self.p50())
            .field("p99", &self.p99())
            .finish()
    }
}

impl Histogram {
    /// Fresh, empty histogram.
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// The bucket index a value lands in (0 for 0, else its bit length).
    pub fn bucket_of(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            (64 - v.leading_zeros()) as usize
        }
    }

    /// Inclusive upper bound of bucket `i` — what quantile readouts
    /// resolve to.
    pub fn bucket_ceiling(i: usize) -> u64 {
        match i {
            0 => 0,
            64.. => u64::MAX,
            _ => (1u64 << i) - 1,
        }
    }

    /// Record one observation.
    pub fn record(&mut self, v: u64) {
        self.record_n(v, 1);
    }

    /// Record `n` observations of the same value (bulk path for replays).
    pub fn record_n(&mut self, v: u64, n: u64) {
        if n == 0 {
            return;
        }
        self.buckets[Histogram::bucket_of(v)] += n;
        self.count += n;
        self.sum = self.sum.saturating_add(v.saturating_mul(n));
    }

    /// Observations recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of recorded values (saturating).
    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// Mean recorded value (0 when empty).
    pub fn mean(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.sum / self.count
        }
    }

    /// True when nothing was recorded.
    pub fn is_zero(&self) -> bool {
        self.count == 0
    }

    /// Occupancy of bucket `i` (0 when out of range).
    pub fn bucket(&self, i: usize) -> u64 {
        self.buckets.get(i).copied().unwrap_or(0)
    }

    /// Non-empty `(bucket index, occupancy)` pairs, ascending — the
    /// sparse form the JSON emit ships.
    pub fn nonzero_buckets(&self) -> Vec<(usize, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter(|(_, n)| **n > 0)
            .map(|(i, n)| (i, *n))
            .collect()
    }

    /// Upper bound of the bucket containing the `q`-quantile observation
    /// (`q` clamped to `[0, 1]`; 0 when empty). Exact to within the 2×
    /// log2 bucket width.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let q = q.clamp(0.0, 1.0);
        // rank of the target observation, 1-based, ceil so q=1.0 is the max
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= target {
                return Histogram::bucket_ceiling(i);
            }
        }
        Histogram::bucket_ceiling(HIST_BUCKETS - 1)
    }

    /// Median bucket ceiling.
    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    /// 95th-percentile bucket ceiling.
    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }

    /// 99th-percentile bucket ceiling.
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Sum another histogram into this one (cross-rank / cross-source
    /// aggregation).
    pub fn merge(&mut self, other: &Histogram) {
        for (b, o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += o;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
    }

    /// Bucket-wise `self − earlier`, clamped at zero — attributes a
    /// monotonically accumulating histogram to one stage/window, exactly
    /// like [`crate::metrics::SpillStats::saturating_diff`].
    pub fn saturating_diff(&self, earlier: &Histogram) -> Histogram {
        let mut out = Histogram::default();
        for (i, (s, e)) in self.buckets.iter().zip(earlier.buckets.iter()).enumerate() {
            out.buckets[i] = s.saturating_sub(*e);
        }
        out.count = self.count.saturating_sub(earlier.count);
        out.sum = self.sum.saturating_sub(earlier.sum);
        out
    }

    /// Rebuild a histogram from its serialized parts (sparse
    /// `(bucket index, occupancy)` pairs). `count` and `sum` are carried
    /// explicitly because `sum` is not derivable from log2 buckets.
    ///
    /// Errors on out-of-range bucket indices (never panics on wire data).
    pub fn from_parts(count: u64, sum: u64, buckets: &[(usize, u64)]) -> Result<Histogram, String> {
        let mut h = Histogram::default();
        for (i, n) in buckets {
            if *i >= HIST_BUCKETS {
                return Err(format!("histogram bucket index {i} out of range"));
            }
            h.buckets[*i] += n;
        }
        h.count = count;
        h.sum = sum;
        Ok(h)
    }

    /// Compact one-line rendering for tables: `n=… mean=… p50=… p99=…`.
    pub fn brief(&self) -> String {
        format!("n={} mean={} p50={} p99={}", self.count, self.mean(), self.p50(), self.p99())
    }
}

/// Named histogram registry: the seam-name → [`Histogram`] map carried by
/// [`crate::metrics::MetricsSnapshot`] (and, as a per-stage delta, by
/// [`crate::metrics::StageTiming`]). `BTreeMap` so iteration — and
/// therefore the JSON emit — is deterministic.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct HistSet {
    hists: BTreeMap<String, Histogram>,
}

impl HistSet {
    /// Fresh, empty set.
    pub fn new() -> HistSet {
        HistSet::default()
    }

    /// Record one observation under `name` (creating the histogram on
    /// first use).
    pub fn record(&mut self, name: &str, v: u64) {
        match self.hists.get_mut(name) {
            Some(h) => h.record(v),
            None => {
                let mut h = Histogram::new();
                h.record(v);
                self.hists.insert(name.to_string(), h);
            }
        }
    }

    /// Insert/replace a whole histogram (test and aggregation helper).
    pub fn insert(&mut self, name: &str, h: Histogram) {
        self.hists.insert(name.to_string(), h);
    }

    /// The histogram under `name`, if any observation was recorded.
    pub fn get(&self, name: &str) -> Option<&Histogram> {
        self.hists.get(name)
    }

    /// True when no histogram holds any observation.
    pub fn is_empty(&self) -> bool {
        self.hists.values().all(|h| h.is_zero())
    }

    /// Iterate `(name, histogram)` in name order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &Histogram)> {
        self.hists.iter().map(|(n, h)| (n.as_str(), h))
    }

    /// Number of named histograms.
    pub fn len(&self) -> usize {
        self.hists.len()
    }

    /// Merge another set into this one: histograms sharing a name merge
    /// bucket-wise, new names are inserted.
    pub fn merge(&mut self, other: &HistSet) {
        for (name, h) in &other.hists {
            match self.hists.get_mut(name) {
                Some(mine) => mine.merge(h),
                None => {
                    self.hists.insert(name.clone(), h.clone());
                }
            }
        }
    }

    /// Per-name `self − earlier` (a name absent from `earlier` diffs
    /// against empty); names whose delta is empty are dropped, so a stage
    /// that recorded nothing under a seam carries no entry for it.
    pub fn saturating_diff(&self, earlier: &HistSet) -> HistSet {
        let mut out = HistSet::new();
        for (name, h) in &self.hists {
            let d = match earlier.hists.get(name) {
                Some(e) => h.saturating_diff(e),
                None => h.clone(),
            };
            if !d.is_zero() {
                out.hists.insert(name.clone(), d);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucketing_is_log2_with_zero_bucket() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 1);
        assert_eq!(Histogram::bucket_of(2), 2);
        assert_eq!(Histogram::bucket_of(3), 2);
        assert_eq!(Histogram::bucket_of(4), 3);
        assert_eq!(Histogram::bucket_of(1023), 10);
        assert_eq!(Histogram::bucket_of(1024), 11);
        assert_eq!(Histogram::bucket_of(u64::MAX), 64);
        assert_eq!(Histogram::bucket_ceiling(0), 0);
        assert_eq!(Histogram::bucket_ceiling(1), 1);
        assert_eq!(Histogram::bucket_ceiling(10), 1023);
        assert_eq!(Histogram::bucket_ceiling(64), u64::MAX);
    }

    #[test]
    fn record_count_sum_mean() {
        let mut h = Histogram::new();
        assert!(h.is_zero());
        assert_eq!(h.quantile(0.5), 0);
        h.record(0);
        h.record(100);
        h.record_n(50, 2);
        assert_eq!(h.count(), 4);
        assert_eq!(h.sum(), 200);
        assert_eq!(h.mean(), 50);
        assert_eq!(h.bucket(0), 1);
        assert_eq!(h.nonzero_buckets().len(), 3); // 0, 50 (bucket 6), 100 (bucket 7)
    }

    #[test]
    fn quantiles_resolve_to_bucket_ceilings() {
        let mut h = Histogram::new();
        for v in [1u64, 1, 1, 1, 1, 1, 1, 1, 1, 1000] {
            h.record(v);
        }
        // 9 of 10 observations in bucket 1 (ceiling 1)
        assert_eq!(h.p50(), 1);
        // the 10th (q=1.0-side) lands in bucket 10 (ceiling 1023)
        assert_eq!(h.quantile(1.0), 1023);
        assert_eq!(h.p99(), 1023, "p99 of 10 obs is the max");
        assert_eq!(h.quantile(0.90), 1, "rank ceil(9.0)=9 is still the small bucket");
    }

    #[test]
    fn merge_sums_and_diff_clamps() {
        let mut a = Histogram::new();
        a.record(10);
        a.record(2000);
        let mut b = Histogram::new();
        b.record(10);
        let mut m = a.clone();
        m.merge(&b);
        assert_eq!(m.count(), 3);
        assert_eq!(m.sum(), 2020);
        assert_eq!(m.bucket(Histogram::bucket_of(10)), 2);
        let d = m.saturating_diff(&a);
        assert_eq!(d, b, "diff recovers exactly what was merged in");
        assert!(a.saturating_diff(&m).is_zero(), "clamped, never negative");
    }

    #[test]
    fn saturating_sum_never_overflows() {
        let mut h = Histogram::new();
        h.record(u64::MAX);
        h.record(u64::MAX);
        assert_eq!(h.sum(), u64::MAX);
        assert_eq!(h.count(), 2);
        assert_eq!(h.quantile(1.0), u64::MAX);
    }

    #[test]
    fn hist_set_records_merges_and_diffs_by_name() {
        let mut a = HistSet::new();
        a.record("lat_ns", 100);
        a.record("lat_ns", 200);
        a.record("bytes", 4096);
        let cut = a.clone(); // window boundary
        a.record("lat_ns", 400);
        a.record("new_seam", 7);
        let d = a.saturating_diff(&cut);
        assert_eq!(d.get("lat_ns").unwrap().count(), 1);
        assert_eq!(d.get("new_seam").unwrap().count(), 1, "absent earlier diffs vs empty");
        assert!(d.get("bytes").is_none(), "empty deltas are dropped");
        let mut m = cut.clone();
        m.merge(&d);
        assert_eq!(m, a, "diff then merge reconstructs the later snapshot");
    }

    #[test]
    fn empty_set_behaviors() {
        let s = HistSet::new();
        assert!(s.is_empty());
        assert_eq!(s.len(), 0);
        assert!(s.saturating_diff(&s).is_empty());
        let mut t = HistSet::new();
        t.merge(&s);
        assert!(t.is_empty());
    }
}

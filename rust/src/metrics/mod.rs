//! Phase metrics — what Fig 6 (communication vs computation breakdown) is
//! made of — plus the observability plane built on top of it
//! (DESIGN.md §14).
//!
//! Each worker tracks wall time per [`Phase`]; the driver aggregates
//! per-rank reports into a [`Breakdown`]. Every counter family
//! accumulates monotonically and is attributed to stages/windows by
//! diffing snapshots (`saturating_diff`). The [`hist`] module adds
//! log2-bucketed latency/size distributions recorded at the hot seams;
//! [`StatsHub`] is the thread-safe accumulator the worker, the comm
//! layer and the telemetry sampler all share; [`MetricsSnapshot`] is the
//! unified point-in-time view (JSON round-trippable via
//! [`MetricsSnapshot::to_json`] / [`MetricsSnapshot::from_json`]);
//! [`TelemetryPublisher`] samples it live from a per-rank thread; and
//! [`cluster_summary`] merges rank snapshots into the gang-wide view the
//! `bench_driver top` monitor and the Prometheus exposition render.

mod hist;
mod json;
mod telemetry;

pub use hist::{HistSet, Histogram, HIST_BUCKETS};
pub use telemetry::{
    TelemetryPublisher, TelemetrySample, TelemetrySink, TelemetrySource, TELEMETRY_RING_CAP,
};

use crate::util::Stopwatch;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

/// The phases distributed operators are decomposed into (paper §III-B:
/// core local operator, auxiliary local operators, communication operators).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    /// Core local compute (local join/groupby/sort kernels).
    Compute,
    /// Auxiliary local work (hash partitioning, split/gather, serde).
    Auxiliary,
    /// Communication (collective routines on the wire / channel).
    Communication,
}

impl Phase {
    /// Stable label for reports.
    pub fn label(&self) -> &'static str {
        match self {
            Phase::Compute => "compute",
            Phase::Auxiliary => "auxiliary",
            Phase::Communication => "communication",
        }
    }
}

/// Per-worker phase timer. Cheap to clone into reports.
#[derive(Debug, Default, Clone)]
pub struct PhaseTimers {
    timers: BTreeMap<Phase, Duration>,
}

// Semantic equality: an explicitly-recorded zero duration and an absent
// entry are the same timer state (so `from_json(to_json(t)) == t` holds
// even when a coarse clock produced a zero-length measurement).
impl PartialEq for PhaseTimers {
    fn eq(&self, other: &Self) -> bool {
        [Phase::Compute, Phase::Auxiliary, Phase::Communication]
            .iter()
            .all(|p| self.get(*p) == other.get(*p))
    }
}

impl Eq for PhaseTimers {}

impl PhaseTimers {
    /// Fresh, all-zero timers.
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f` under `phase`.
    pub fn time<T>(&mut self, phase: Phase, f: impl FnOnce() -> T) -> T {
        let mut sw = Stopwatch::new();
        let out = sw.time(f);
        self.add(phase, sw.elapsed());
        out
    }

    /// Add a pre-measured duration to `phase`.
    pub fn add(&mut self, phase: Phase, d: Duration) {
        *self.timers.entry(phase).or_default() += d;
    }

    /// Accumulated duration for `phase`.
    pub fn get(&self, phase: Phase) -> Duration {
        self.timers.get(&phase).copied().unwrap_or_default()
    }

    /// Total across phases.
    pub fn total(&self) -> Duration {
        self.timers.values().sum()
    }

    /// Merge another report into this one (sums).
    pub fn merge(&mut self, other: &PhaseTimers) {
        for (p, d) in &other.timers {
            *self.timers.entry(*p).or_default() += *d;
        }
    }

    /// Reset all timers to zero.
    pub fn reset(&mut self) {
        self.timers.clear();
    }

    /// Per-phase `self − earlier`, clamped at zero — used to attribute a
    /// monotonically accumulating timer snapshot to one pipeline stage.
    pub fn saturating_diff(&self, earlier: &PhaseTimers) -> PhaseTimers {
        let mut out = PhaseTimers::new();
        for (p, d) in &self.timers {
            let before = earlier.get(*p);
            if *d > before {
                out.add(*p, *d - before);
            }
        }
        out
    }
}

/// Out-of-core exchange counters: how much shuffle/allgather payload
/// overflowed the in-memory budget onto disk (see
/// [`crate::store::SpillBuffer`]). Like [`PhaseTimers`] these accumulate
/// monotonically per worker and are attributed to stages by diffing
/// snapshots.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct SpillStats {
    /// Frame bytes written to spill files.
    pub spilled_bytes: u64,
    /// Number of frames that overflowed to disk.
    pub spill_count: u64,
}

impl SpillStats {
    /// True when nothing spilled.
    pub fn is_zero(&self) -> bool {
        self.spilled_bytes == 0 && self.spill_count == 0
    }

    /// Sum another snapshot into this one.
    pub fn merge(&mut self, other: &SpillStats) {
        self.spilled_bytes += other.spilled_bytes;
        self.spill_count += other.spill_count;
    }

    /// Per-counter `self − earlier`, clamped at zero — attributes a
    /// monotonically accumulating snapshot to one stage, exactly like
    /// [`PhaseTimers::saturating_diff`].
    pub fn saturating_diff(&self, earlier: &SpillStats) -> SpillStats {
        SpillStats {
            spilled_bytes: self.spilled_bytes.saturating_sub(earlier.spilled_bytes),
            spill_count: self.spill_count.saturating_sub(earlier.spill_count),
        }
    }
}

/// Overlapped-exchange counters (see [`crate::comm::nb`] and
/// [`crate::comm::algorithms::all_to_all_overlapped`]): how much of an
/// exchange's compute ran while wire requests were in flight — the
/// communication/computation overlap the double-buffered path exists to
/// create. Like [`SpillStats`] these accumulate monotonically per worker
/// and are attributed to stages by diffing snapshots. All zero when the
/// overlap path is disabled (the default).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct OverlapStats {
    /// Frames encoded or delivered to the spill sink while the wire was
    /// demonstrably active — a submitted send not yet reaped, or an
    /// arrived frame awaiting decode. These are the chunks whose compute
    /// the blocking path would have serialized against the wire.
    /// (A merely-posted, unmatched receive does not count, so the number
    /// stays zero when there is genuinely nothing to overlap.)
    pub chunks_overlapped: u64,
    /// Nanoseconds of encode/decode/spill work performed while the wire
    /// was busy (same definition as `chunks_overlapped`): wire-idle time
    /// the overlap hid under compute.
    pub hidden_nanos: u64,
    /// Nanoseconds spent submitting, reaping and *blocking on* wire
    /// requests: compute-idle time the overlap could not hide. With
    /// perfect overlap this approaches the bare submission overhead.
    pub wire_wait_nanos: u64,
}

impl OverlapStats {
    /// True when no overlapped exchange ran.
    pub fn is_zero(&self) -> bool {
        *self == OverlapStats::default()
    }

    /// Sum another snapshot into this one.
    pub fn merge(&mut self, other: &OverlapStats) {
        self.chunks_overlapped += other.chunks_overlapped;
        self.hidden_nanos += other.hidden_nanos;
        self.wire_wait_nanos += other.wire_wait_nanos;
    }

    /// Per-counter `self − earlier`, clamped at zero — attributes a
    /// monotonically accumulating snapshot to one stage, exactly like
    /// [`SpillStats::saturating_diff`].
    pub fn saturating_diff(&self, earlier: &OverlapStats) -> OverlapStats {
        OverlapStats {
            chunks_overlapped: self.chunks_overlapped.saturating_sub(earlier.chunks_overlapped),
            hidden_nanos: self.hidden_nanos.saturating_sub(earlier.hidden_nanos),
            wire_wait_nanos: self.wire_wait_nanos.saturating_sub(earlier.wire_wait_nanos),
        }
    }
}

/// Skew-aware repartitioning counters (see [`crate::dist::skew`]): what
/// the hot-key detector found and how much the split-assignment plan
/// moved. Like [`SpillStats`] these accumulate monotonically per worker
/// ([`crate::executor::CylonEnv::record_skew`]) and are attributed to
/// stages by diffing snapshots.
///
/// The ratio fields hold the **max/mean partition row ratio** of the
/// exchange, `×1000` (so they stay integer, `Eq` and diff-able): `1000`
/// means perfectly balanced, `4000` means the fullest rank received 4×
/// the mean. `_before` simulates the plain `hash mod p` routing of the
/// same rows; `_after` is the routing the skew plan actually performed.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct SkewStats {
    /// Distinct hot key-hash groups the estimator flagged.
    pub hot_keys: u64,
    /// Rows routed by the split-assignment (salted/replicated) path
    /// instead of plain `hash mod p`.
    pub rows_rerouted: u64,
    /// Max/mean partition row ratio under plain hashing, ×1000.
    pub ratio_before_milli: u64,
    /// Max/mean partition row ratio under the skew plan, ×1000.
    pub ratio_after_milli: u64,
}

impl SkewStats {
    /// True when no skew handling engaged.
    pub fn is_zero(&self) -> bool {
        *self == SkewStats::default()
    }

    /// Fold another snapshot in for *aggregation* (across ranks or
    /// stages): counters sum, ratios keep the worst (max) observation —
    /// "how bad did it get before/after".
    pub fn merge(&mut self, other: &SkewStats) {
        self.hot_keys += other.hot_keys;
        self.rows_rerouted += other.rows_rerouted;
        self.ratio_before_milli = self.ratio_before_milli.max(other.ratio_before_milli);
        self.ratio_after_milli = self.ratio_after_milli.max(other.ratio_after_milli);
    }

    /// Fold one exchange's counters into a worker's *running* stats
    /// ([`crate::executor::CylonEnv::record_skew`]): counters sum, but
    /// the ratio fields take the **latest** observation, so a stage
    /// snapshot diff reports the ratios of that stage's own exchange
    /// rather than the worst seen anywhere in the run.
    pub fn observe(&mut self, obs: &SkewStats) {
        self.hot_keys += obs.hot_keys;
        self.rows_rerouted += obs.rows_rerouted;
        self.ratio_before_milli = obs.ratio_before_milli;
        self.ratio_after_milli = obs.ratio_after_milli;
    }

    /// Attribute a monotonic snapshot to one stage: counters subtract
    /// (clamped); the ratio fields are carried from `self` only when the
    /// stage actually engaged skew handling (counter delta non-zero) —
    /// with [`SkewStats::observe`] accumulation they then hold the
    /// stage's own most recent exchange, since ratios are per-exchange
    /// observations, not running sums.
    pub fn saturating_diff(&self, earlier: &SkewStats) -> SkewStats {
        let hot_keys = self.hot_keys.saturating_sub(earlier.hot_keys);
        let rows_rerouted = self.rows_rerouted.saturating_sub(earlier.rows_rerouted);
        if hot_keys == 0 && rows_rerouted == 0 {
            return SkewStats::default();
        }
        SkewStats {
            hot_keys,
            rows_rerouted,
            ratio_before_milli: self.ratio_before_milli,
            ratio_after_milli: self.ratio_after_milli,
        }
    }
}

/// Morsel-executor counters (see [`crate::executor::MorselPool`] and
/// DESIGN.md §11): how much work the intra-rank worker pool ran and how
/// well it kept its workers fed. Like [`SpillStats`] these accumulate
/// monotonically per worker and are attributed to stages by diffing
/// snapshots. All zero when the pool is disabled (the default) — the
/// serial path never touches them.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct LocalStats {
    /// Morsels (parallel task units) executed by the pool.
    pub morsels: u64,
    /// Nanoseconds pool workers spent running morsel bodies, summed
    /// across workers (can exceed wall time — that is the point).
    pub busy_nanos: u64,
    /// Nanoseconds pool workers spent idle inside parallel regions
    /// (region wall × workers − busy): scheduling overhead plus
    /// tail-of-region starvation.
    pub idle_nanos: u64,
}

impl LocalStats {
    /// True when the pool ran nothing.
    pub fn is_zero(&self) -> bool {
        *self == LocalStats::default()
    }

    /// Sum another snapshot into this one.
    pub fn merge(&mut self, other: &LocalStats) {
        self.morsels += other.morsels;
        self.busy_nanos += other.busy_nanos;
        self.idle_nanos += other.idle_nanos;
    }

    /// Per-counter `self − earlier`, clamped at zero — attributes a
    /// monotonically accumulating snapshot to one stage, exactly like
    /// [`SpillStats::saturating_diff`].
    pub fn saturating_diff(&self, earlier: &LocalStats) -> LocalStats {
        LocalStats {
            morsels: self.morsels.saturating_sub(earlier.morsels),
            busy_nanos: self.busy_nanos.saturating_sub(earlier.busy_nanos),
            idle_nanos: self.idle_nanos.saturating_sub(earlier.idle_nanos),
        }
    }
}

/// Phase timers attributed to one pipeline/plan stage (delta of the
/// actor's monotonically accumulating timers across the stage,
/// communication included). Emitted per executed plan node by
/// [`crate::plan`]'s executor and surfaced through
/// [`crate::dist::pipeline()`]'s report.
#[derive(Debug, Clone)]
pub struct StageTiming {
    /// Stage label (`join`, `groupby`, `sort`, `add_scalar`, …).
    pub name: String,
    /// Compute / auxiliary / communication spent inside the stage.
    pub timers: PhaseTimers,
    /// Exchange bytes/frames this stage spilled to disk (zero below the
    /// memory budget).
    pub spill: SpillStats,
    /// Hot keys / rerouted rows the skew subsystem handled in this stage
    /// (zero when skew handling is disabled or found nothing).
    pub skew: SkewStats,
    /// Communication/computation overlap this stage's exchanges achieved
    /// (zero when the overlap path is disabled, the default).
    pub overlap: OverlapStats,
    /// Morsel-pool work this stage's local operators ran across cores
    /// (zero when intra-rank parallelism is disabled, the default).
    pub local: LocalStats,
    /// Latency/size distributions the stage's hot seams recorded
    /// (per-name delta of the actor's monotonic [`HistSet`]; empty seams
    /// are dropped, see [`HistSet::saturating_diff`]).
    pub hists: HistSet,
}

/// One worker's unified metrics view at a point in time: every
/// monotonically accumulating counter family the runtime keeps (phase
/// timers, spill, skew, overlap) plus a free-form named-counter
/// registry, snapshotted together. This is what
/// [`crate::executor::CylonEnv::snapshot`] returns — the single
/// replacement for the former per-family accessors — and what the plan
/// executor diffs across stage boundaries.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct MetricsSnapshot {
    /// Compute / auxiliary / communication wall time.
    pub timers: PhaseTimers,
    /// Out-of-core exchange counters.
    pub spill: SpillStats,
    /// Skew-aware repartitioning counters.
    pub skew: SkewStats,
    /// Communication/computation overlap counters.
    pub overlap: OverlapStats,
    /// Morsel-executor (intra-rank parallelism) counters.
    pub local: LocalStats,
    /// Named counters that don't belong to a structured family
    /// (`bytes_sent`, `trace_events_recorded`, …), sorted by name so the
    /// JSON emit is deterministic.
    pub counters: Vec<(String, u64)>,
    /// Latency/size distributions recorded at the hot seams
    /// (`stage_duration_ns`, `collective_ns`, `spill_write_bytes`, … —
    /// see DESIGN.md §14 for the seam inventory).
    pub hists: HistSet,
}

impl MetricsSnapshot {
    /// Look up a named counter (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| *v)
            .unwrap_or(0)
    }

    /// Attribute the window between two snapshots: every family diffs
    /// with its own `saturating_diff` rules; named counters are matched
    /// by name and clamped at zero (a counter absent from `earlier`
    /// diffs against 0).
    pub fn saturating_diff(&self, earlier: &MetricsSnapshot) -> MetricsSnapshot {
        MetricsSnapshot {
            timers: self.timers.saturating_diff(&earlier.timers),
            spill: self.spill.saturating_diff(&earlier.spill),
            skew: self.skew.saturating_diff(&earlier.skew),
            overlap: self.overlap.saturating_diff(&earlier.overlap),
            local: self.local.saturating_diff(&earlier.local),
            counters: self
                .counters
                .iter()
                .map(|(n, v)| (n.clone(), v.saturating_sub(earlier.counter(n))))
                .collect(),
            hists: self.hists.saturating_diff(&earlier.hists),
        }
    }

    /// Fold another snapshot into this one for *aggregation* (across
    /// ranks): timers, spill, overlap, local and named counters sum;
    /// histograms merge bucket-wise; skew follows [`SkewStats::merge`]
    /// (counters sum, ratios keep the worst observation). This is the
    /// pairwise step [`cluster_summary`] folds a gang with.
    pub fn merge(&mut self, other: &MetricsSnapshot) {
        self.timers.merge(&other.timers);
        self.spill.merge(&other.spill);
        self.skew.merge(&other.skew);
        self.overlap.merge(&other.overlap);
        self.local.merge(&other.local);
        for (name, v) in &other.counters {
            match self.counters.iter_mut().find(|(n, _)| n == name) {
                Some((_, mine)) => *mine += v,
                None => self.counters.push((name.clone(), *v)),
            }
        }
        self.counters.sort();
        self.hists.merge(&other.hists);
    }

    /// Machine-readable JSON object, hand-rolled in the same stable
    /// flat-key style as the bench records (every value an integer, keys
    /// never reordered):
    ///
    /// ```json
    /// {"compute_ns": 0, "auxiliary_ns": 0, "communication_ns": 0,
    ///  "spilled_bytes": 0, "spill_count": 0,
    ///  "hot_keys": 0, "rows_rerouted": 0,
    ///  "ratio_before_milli": 0, "ratio_after_milli": 0,
    ///  "chunks_overlapped": 0, "hidden_ns": 0, "wire_wait_ns": 0,
    ///  "local_morsels": 0, "local_busy_ns": 0, "local_idle_ns": 0,
    ///  "counters": {"bytes_sent": 0},
    ///  "hists": {"collective_ns": {"count": 2, "sum": 900, "buckets": {"9": 2}}}}
    /// ```
    ///
    /// Histograms ship sparse (`buckets` maps log2 bucket index →
    /// occupancy; empty buckets are omitted). [`MetricsSnapshot::from_json`]
    /// reads this exact surface back, so the whole metrics plane is
    /// round-trippable: `from_json(to_json(s)) == s`.
    pub fn to_json(&self) -> String {
        let counters = self
            .counters
            .iter()
            .map(|(n, v)| format!("\"{n}\": {v}"))
            .collect::<Vec<_>>()
            .join(", ");
        let hists = self
            .hists
            .iter()
            .map(|(name, h)| {
                let buckets = h
                    .nonzero_buckets()
                    .iter()
                    .map(|(i, n)| format!("\"{i}\": {n}"))
                    .collect::<Vec<_>>()
                    .join(", ");
                format!(
                    "\"{name}\": {{\"count\": {}, \"sum\": {}, \"buckets\": {{{buckets}}}}}",
                    h.count(),
                    h.sum()
                )
            })
            .collect::<Vec<_>>()
            .join(", ");
        format!(
            concat!(
                "{{\"compute_ns\": {}, \"auxiliary_ns\": {}, \"communication_ns\": {}, ",
                "\"spilled_bytes\": {}, \"spill_count\": {}, ",
                "\"hot_keys\": {}, \"rows_rerouted\": {}, ",
                "\"ratio_before_milli\": {}, \"ratio_after_milli\": {}, ",
                "\"chunks_overlapped\": {}, \"hidden_ns\": {}, \"wire_wait_ns\": {}, ",
                "\"local_morsels\": {}, \"local_busy_ns\": {}, \"local_idle_ns\": {}, ",
                "\"counters\": {{{}}}, \"hists\": {{{}}}}}"
            ),
            self.timers.get(Phase::Compute).as_nanos(),
            self.timers.get(Phase::Auxiliary).as_nanos(),
            self.timers.get(Phase::Communication).as_nanos(),
            self.spill.spilled_bytes,
            self.spill.spill_count,
            self.skew.hot_keys,
            self.skew.rows_rerouted,
            self.skew.ratio_before_milli,
            self.skew.ratio_after_milli,
            self.overlap.chunks_overlapped,
            self.overlap.hidden_nanos,
            self.overlap.wire_wait_nanos,
            self.local.morsels,
            self.local.busy_nanos,
            self.local.idle_nanos,
            counters,
            hists,
        )
    }

    /// Parse a snapshot back from [`MetricsSnapshot::to_json`]'s output
    /// (the inverse: `from_json(to_json(s)) == s`, property-tested in
    /// `tests/telemetry.rs`). Missing numeric fields read as 0 and
    /// unknown keys are ignored, so older/newer emitters interoperate;
    /// structurally malformed input is an error, never a panic.
    ///
    /// # Errors
    /// [`crate::error::Error::InvalidArgument`] naming the parse failure
    /// (truncated object, non-numeric field, out-of-range bucket index).
    pub fn from_json(text: &str) -> crate::error::Result<MetricsSnapshot> {
        let obj = json::parse_object(text)
            .map_err(|e| crate::error::Error::invalid(format!("metrics json: {e}")))?;
        MetricsSnapshot::from_parsed(&obj)
            .map_err(|e| crate::error::Error::invalid(format!("metrics json: {e}")))
    }

    /// Build from an already-parsed object (shared with the telemetry
    /// sample parser, which embeds snapshots as nested objects).
    pub(crate) fn from_parsed(obj: &json::JsonVal) -> Result<MetricsSnapshot, String> {
        let mut timers = PhaseTimers::new();
        for (phase, key) in [
            (Phase::Compute, "compute_ns"),
            (Phase::Auxiliary, "auxiliary_ns"),
            (Phase::Communication, "communication_ns"),
        ] {
            let ns = obj.num(key)?;
            if ns > 0 {
                timers.add(phase, Duration::from_nanos(ns));
            }
        }
        let mut counters = Vec::new();
        if let Some(c) = obj.field("counters") {
            for (name, v) in c.fields() {
                match v {
                    json::JsonVal::Num(n) => counters.push((name.clone(), *n)),
                    other => return Err(format!("counter {name:?} is not a number: {other:?}")),
                }
            }
        }
        let mut hists = HistSet::new();
        if let Some(hs) = obj.field("hists") {
            for (name, h) in hs.fields() {
                let mut pairs = Vec::new();
                if let Some(buckets) = h.field("buckets") {
                    for (idx, n) in buckets.fields() {
                        let i: usize = idx
                            .parse()
                            .map_err(|_| format!("bad bucket index {idx:?} in {name:?}"))?;
                        match n {
                            json::JsonVal::Num(n) => pairs.push((i, *n)),
                            other => {
                                return Err(format!("bucket {idx:?} is not a number: {other:?}"))
                            }
                        }
                    }
                }
                hists.insert(name, Histogram::from_parts(h.num("count")?, h.num("sum")?, &pairs)?);
            }
        }
        Ok(MetricsSnapshot {
            timers,
            spill: SpillStats {
                spilled_bytes: obj.num("spilled_bytes")?,
                spill_count: obj.num("spill_count")?,
            },
            skew: SkewStats {
                hot_keys: obj.num("hot_keys")?,
                rows_rerouted: obj.num("rows_rerouted")?,
                ratio_before_milli: obj.num("ratio_before_milli")?,
                ratio_after_milli: obj.num("ratio_after_milli")?,
            },
            overlap: OverlapStats {
                chunks_overlapped: obj.num("chunks_overlapped")?,
                hidden_nanos: obj.num("hidden_ns")?,
                wire_wait_nanos: obj.num("wire_wait_ns")?,
            },
            local: LocalStats {
                morsels: obj.num("local_morsels")?,
                busy_nanos: obj.num("local_busy_ns")?,
                idle_nanos: obj.num("local_idle_ns")?,
            },
            counters,
            hists,
        })
    }

    /// One-line human summary (what the examples print at exit).
    pub fn summary(&self) -> String {
        format!(
            "metrics: compute={:.1}ms auxiliary={:.1}ms communication={:.1}ms \
             spilled={}B skew_rerouted={} overlapped={} morsels={} bytes_sent={}",
            self.timers.get(Phase::Compute).as_secs_f64() * 1e3,
            self.timers.get(Phase::Auxiliary).as_secs_f64() * 1e3,
            self.timers.get(Phase::Communication).as_secs_f64() * 1e3,
            self.spill.spilled_bytes,
            self.skew.rows_rerouted,
            self.overlap.chunks_overlapped,
            self.local.morsels,
            self.counter("bytes_sent"),
        )
    }
}

/// Aggregated comm/compute breakdown across a gang of workers.
#[derive(Debug, Clone)]
pub struct Breakdown {
    /// Per-rank timer snapshots.
    pub per_rank: Vec<PhaseTimers>,
}

impl Breakdown {
    /// Build from per-rank snapshots.
    pub fn new(per_rank: Vec<PhaseTimers>) -> Self {
        Breakdown { per_rank }
    }

    /// Mean duration of `phase` across ranks.
    pub fn mean(&self, phase: Phase) -> Duration {
        if self.per_rank.is_empty() {
            return Duration::ZERO;
        }
        let sum: Duration = self.per_rank.iter().map(|t| t.get(phase)).sum();
        sum / self.per_rank.len() as u32
    }

    /// Max duration of `phase` across ranks (the BSP critical path).
    pub fn max(&self, phase: Phase) -> Duration {
        self.per_rank
            .iter()
            .map(|t| t.get(phase))
            .max()
            .unwrap_or_default()
    }

    /// Fraction of mean wall time spent in communication — the Fig 6 y-axis.
    pub fn comm_fraction(&self) -> f64 {
        let comm = self.mean(Phase::Communication).as_secs_f64();
        let total: f64 = [Phase::Compute, Phase::Auxiliary, Phase::Communication]
            .iter()
            .map(|p| self.mean(*p).as_secs_f64())
            .sum();
        if total == 0.0 {
            0.0
        } else {
            comm / total
        }
    }

    /// One-line report: `compute=…ms auxiliary=…ms communication=…ms (x%)`.
    pub fn report(&self) -> String {
        format!(
            "compute={:.1}ms auxiliary={:.1}ms communication={:.1}ms (comm {:.0}%)",
            self.mean(Phase::Compute).as_secs_f64() * 1e3,
            self.mean(Phase::Auxiliary).as_secs_f64() * 1e3,
            self.mean(Phase::Communication).as_secs_f64() * 1e3,
            self.comm_fraction() * 100.0
        )
    }
}

/// Thread-safe accumulator of every metrics family one actor keeps.
///
/// Two hubs exist per worker — one owned by [`crate::executor::CylonEnv`]
/// (worker-side timers, skew observations, the named-counter registry,
/// the current-stage label and the stage-duration histograms) and one
/// owned by [`crate::comm::CommContext`] (communication timers,
/// spill/overlap counters, wire-seam histograms) — both `Arc`-shared so
/// the [`TelemetryPublisher`] sampler thread can read a consistent
/// [`MetricsSnapshot`] while the worker thread is deep inside an
/// operator. Every family keeps the established monotonic
/// accumulate-then-diff discipline; the hub only moves the storage
/// behind mutexes (uncontended in the common case — the sampler touches
/// them a few times per second).
#[derive(Debug, Default)]
pub struct StatsHub {
    timers: Mutex<PhaseTimers>,
    spill: Mutex<SpillStats>,
    skew: Mutex<SkewStats>,
    overlap: Mutex<OverlapStats>,
    hists: Mutex<HistSet>,
    counters: Mutex<BTreeMap<String, u64>>,
    stage: Mutex<String>,
}

impl StatsHub {
    /// Fresh, all-zero hub.
    pub fn new() -> StatsHub {
        StatsHub::default()
    }

    /// Time `f` under `phase`.
    pub fn time<T>(&self, phase: Phase, f: impl FnOnce() -> T) -> T {
        let mut sw = Stopwatch::new();
        let out = sw.time(f);
        self.add_phase(phase, sw.elapsed());
        out
    }

    /// Add a pre-measured duration to `phase`.
    pub fn add_phase(&self, phase: Phase, d: Duration) {
        self.timers.lock().expect("timers poisoned").add(phase, d);
    }

    /// Non-destructive snapshot of the phase timers.
    pub fn peek_timers(&self) -> PhaseTimers {
        self.timers.lock().expect("timers poisoned").clone()
    }

    /// Snapshot and reset the phase timers.
    pub fn take_timers(&self) -> PhaseTimers {
        let mut t = self.timers.lock().expect("timers poisoned");
        let snap = t.clone();
        t.reset();
        snap
    }

    /// Sum spill counters into the hub (no-op when zero).
    pub fn record_spill(&self, stats: SpillStats) {
        if !stats.is_zero() {
            self.spill.lock().expect("spill poisoned").merge(&stats);
        }
    }

    /// Non-destructive snapshot of the spill counters.
    pub fn peek_spill(&self) -> SpillStats {
        *self.spill.lock().expect("spill poisoned")
    }

    /// Snapshot and reset the spill counters.
    pub fn take_spill(&self) -> SpillStats {
        let mut s = self.spill.lock().expect("spill poisoned");
        let snap = *s;
        *s = SpillStats::default();
        snap
    }

    /// Sum overlap counters into the hub (no-op when zero).
    pub fn record_overlap(&self, stats: OverlapStats) {
        if !stats.is_zero() {
            self.overlap.lock().expect("overlap poisoned").merge(&stats);
        }
    }

    /// Non-destructive snapshot of the overlap counters.
    pub fn peek_overlap(&self) -> OverlapStats {
        *self.overlap.lock().expect("overlap poisoned")
    }

    /// Snapshot and reset the overlap counters.
    pub fn take_overlap(&self) -> OverlapStats {
        let mut s = self.overlap.lock().expect("overlap poisoned");
        let snap = *s;
        *s = OverlapStats::default();
        snap
    }

    /// Fold one exchange's skew observation into the running stats
    /// ([`SkewStats::observe`] semantics: counters sum, ratios latest).
    pub fn observe_skew(&self, obs: &SkewStats) {
        self.skew.lock().expect("skew poisoned").observe(obs);
    }

    /// Non-destructive snapshot of the skew counters.
    pub fn peek_skew(&self) -> SkewStats {
        *self.skew.lock().expect("skew poisoned")
    }

    /// Record one histogram observation under a seam name.
    pub fn record_hist(&self, name: &str, v: u64) {
        self.hists.lock().expect("hists poisoned").record(name, v);
    }

    /// Non-destructive snapshot of the named histograms.
    pub fn peek_hists(&self) -> HistSet {
        self.hists.lock().expect("hists poisoned").clone()
    }

    /// Add `by` to the named counter (creating it at zero first). Safe
    /// from any thread — the counter registry is what the concurrent
    /// morsel-pool test hammers.
    pub fn bump_counter(&self, name: &str, by: u64) {
        *self.counters.lock().expect("counters poisoned").entry(name.to_string()).or_insert(0) +=
            by;
    }

    /// Raise the named counter to at least `v` (gauge-style maximum).
    pub fn set_counter_max(&self, name: &str, v: u64) {
        let mut c = self.counters.lock().expect("counters poisoned");
        let e = c.entry(name.to_string()).or_insert(0);
        *e = (*e).max(v);
    }

    /// The named-counter registry, sorted by name.
    pub fn counters(&self) -> Vec<(String, u64)> {
        self.counters
            .lock()
            .expect("counters poisoned")
            .iter()
            .map(|(n, v)| (n.clone(), *v))
            .collect()
    }

    /// Publish the label of the stage the worker is currently executing
    /// (read by the telemetry sampler for the live `top` view).
    pub fn set_stage(&self, label: &str) {
        let mut s = self.stage.lock().expect("stage poisoned");
        s.clear();
        s.push_str(label);
    }

    /// The most recently published stage label ("" before any stage).
    pub fn current_stage(&self) -> String {
        self.stage.lock().expect("stage poisoned").clone()
    }
}

/// Gang-wide aggregation of per-rank snapshots: the merged whole plus
/// how many ranks contributed. Built by [`cluster_summary`]; rendered as
/// a text table ([`ClusterSummary::table`]) or Prometheus-style
/// exposition ([`ClusterSummary::prometheus`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClusterSummary {
    /// Ranks aggregated.
    pub ranks: usize,
    /// Every family merged: counters/timers/spill/overlap/local summed,
    /// histograms merged bucket-wise, skew ratios kept at the worst
    /// observation ([`MetricsSnapshot::merge`]).
    pub merged: MetricsSnapshot,
}

/// Merge per-rank snapshots into one [`ClusterSummary`]. Folding is
/// pairwise [`MetricsSnapshot::merge`], so summarizing `[a, b, c]`
/// equals merging the ranks into one snapshot by hand — the equivalence
/// `tests/telemetry.rs` pins.
pub fn cluster_summary(per_rank: &[MetricsSnapshot]) -> ClusterSummary {
    let mut merged = MetricsSnapshot::default();
    for s in per_rank {
        merged.merge(s);
    }
    ClusterSummary { ranks: per_rank.len(), merged }
}

impl ClusterSummary {
    /// Aligned text table of the merged families and histogram quantiles.
    pub fn table(&self) -> String {
        let m = &self.merged;
        let mut out = String::new();
        out.push_str(&format!("cluster summary ({} ranks)\n", self.ranks));
        out.push_str(&format!(
            "  {:<22} compute={:?} auxiliary={:?} communication={:?}\n",
            "phase",
            m.timers.get(Phase::Compute),
            m.timers.get(Phase::Auxiliary),
            m.timers.get(Phase::Communication),
        ));
        out.push_str(&format!(
            "  {:<22} spilled_bytes={} spill_count={}\n",
            "spill", m.spill.spilled_bytes, m.spill.spill_count
        ));
        out.push_str(&format!(
            "  {:<22} hot_keys={} rows_rerouted={} worst_ratio_before={} worst_ratio_after={}\n",
            "skew",
            m.skew.hot_keys,
            m.skew.rows_rerouted,
            m.skew.ratio_before_milli,
            m.skew.ratio_after_milli
        ));
        out.push_str(&format!(
            "  {:<22} chunks={} hidden_ns={} wire_wait_ns={}\n",
            "overlap", m.overlap.chunks_overlapped, m.overlap.hidden_nanos, m.overlap.wire_wait_nanos
        ));
        out.push_str(&format!(
            "  {:<22} morsels={} busy_ns={} idle_ns={}\n",
            "local", m.local.morsels, m.local.busy_nanos, m.local.idle_nanos
        ));
        for (name, v) in &m.counters {
            out.push_str(&format!("  counter {name:<14} {v}\n"));
        }
        for (name, h) in m.hists.iter() {
            out.push_str(&format!("  hist    {name:<22} {}\n", h.brief()));
        }
        out
    }

    /// Prometheus-style exposition of the merged snapshot: one
    /// `cylonflow_*` sample per scalar, `cylonflow_counter{name="…"}`
    /// for the registry, and cumulative
    /// `cylonflow_hist_bucket{seam="…",le="…"}` series (ending in
    /// `le="+Inf"`) plus `_count`/`_sum` per histogram — the text format
    /// a scraper ingests from a metrics endpoint or a pushed file.
    pub fn prometheus(&self) -> String {
        let m = &self.merged;
        let mut out = String::new();
        out.push_str(&format!("cylonflow_ranks {}\n", self.ranks));
        for (name, v) in [
            ("cylonflow_compute_ns", m.timers.get(Phase::Compute).as_nanos() as u64),
            ("cylonflow_auxiliary_ns", m.timers.get(Phase::Auxiliary).as_nanos() as u64),
            ("cylonflow_communication_ns", m.timers.get(Phase::Communication).as_nanos() as u64),
            ("cylonflow_spilled_bytes", m.spill.spilled_bytes),
            ("cylonflow_spill_count", m.spill.spill_count),
            ("cylonflow_skew_hot_keys", m.skew.hot_keys),
            ("cylonflow_skew_rows_rerouted", m.skew.rows_rerouted),
            ("cylonflow_skew_ratio_before_milli", m.skew.ratio_before_milli),
            ("cylonflow_skew_ratio_after_milli", m.skew.ratio_after_milli),
            ("cylonflow_overlap_chunks", m.overlap.chunks_overlapped),
            ("cylonflow_overlap_hidden_ns", m.overlap.hidden_nanos),
            ("cylonflow_overlap_wire_wait_ns", m.overlap.wire_wait_nanos),
            ("cylonflow_local_morsels", m.local.morsels),
            ("cylonflow_local_busy_ns", m.local.busy_nanos),
            ("cylonflow_local_idle_ns", m.local.idle_nanos),
        ] {
            out.push_str(&format!("{name} {v}\n"));
        }
        for (name, v) in &m.counters {
            out.push_str(&format!("cylonflow_counter{{name=\"{name}\"}} {v}\n"));
        }
        for (name, h) in m.hists.iter() {
            let mut cum = 0u64;
            for (i, n) in h.nonzero_buckets() {
                cum += n;
                out.push_str(&format!(
                    "cylonflow_hist_bucket{{seam=\"{name}\",le=\"{}\"}} {cum}\n",
                    Histogram::bucket_ceiling(i)
                ));
            }
            out.push_str(&format!(
                "cylonflow_hist_bucket{{seam=\"{name}\",le=\"+Inf\"}} {}\n",
                h.count()
            ));
            out.push_str(&format!("cylonflow_hist_count{{seam=\"{name}\"}} {}\n", h.count()));
            out.push_str(&format!("cylonflow_hist_sum{{seam=\"{name}\"}} {}\n", h.sum()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timers_accumulate_and_merge() {
        let mut t = PhaseTimers::new();
        t.add(Phase::Compute, Duration::from_millis(10));
        t.add(Phase::Compute, Duration::from_millis(5));
        t.add(Phase::Communication, Duration::from_millis(15));
        assert_eq!(t.get(Phase::Compute), Duration::from_millis(15));
        let mut u = PhaseTimers::new();
        u.merge(&t);
        u.merge(&t);
        assert_eq!(u.total(), Duration::from_millis(60));
    }

    #[test]
    fn breakdown_fractions() {
        let mut a = PhaseTimers::new();
        a.add(Phase::Compute, Duration::from_millis(30));
        a.add(Phase::Communication, Duration::from_millis(10));
        let b = a.clone();
        let br = Breakdown::new(vec![a, b]);
        assert!((br.comm_fraction() - 0.25).abs() < 1e-9);
        assert_eq!(br.max(Phase::Compute), Duration::from_millis(30));
        assert!(br.report().contains("comm 25%"));
    }

    #[test]
    fn saturating_diff_attributes_deltas() {
        let mut before = PhaseTimers::new();
        before.add(Phase::Compute, Duration::from_millis(10));
        before.add(Phase::Communication, Duration::from_millis(4));
        let mut after = before.clone();
        after.add(Phase::Compute, Duration::from_millis(5));
        after.add(Phase::Auxiliary, Duration::from_millis(2));
        let d = after.saturating_diff(&before);
        assert_eq!(d.get(Phase::Compute), Duration::from_millis(5));
        assert_eq!(d.get(Phase::Auxiliary), Duration::from_millis(2));
        assert_eq!(d.get(Phase::Communication), Duration::ZERO);
        // clamped: diff against a later snapshot is zero, not negative
        assert_eq!(before.saturating_diff(&after).total(), Duration::ZERO);
    }

    #[test]
    fn spill_stats_merge_and_diff() {
        let mut a = SpillStats::default();
        assert!(a.is_zero());
        a.merge(&SpillStats { spilled_bytes: 100, spill_count: 2 });
        a.merge(&SpillStats { spilled_bytes: 50, spill_count: 1 });
        assert_eq!(a, SpillStats { spilled_bytes: 150, spill_count: 3 });
        let earlier = SpillStats { spilled_bytes: 100, spill_count: 2 };
        assert_eq!(
            a.saturating_diff(&earlier),
            SpillStats { spilled_bytes: 50, spill_count: 1 }
        );
        // clamped, never negative
        assert!(earlier.saturating_diff(&a).is_zero());
    }

    #[test]
    fn overlap_stats_merge_and_diff() {
        let mut a = OverlapStats::default();
        assert!(a.is_zero());
        a.merge(&OverlapStats { chunks_overlapped: 4, hidden_nanos: 900, wire_wait_nanos: 100 });
        a.merge(&OverlapStats { chunks_overlapped: 1, hidden_nanos: 100, wire_wait_nanos: 50 });
        assert_eq!(
            a,
            OverlapStats { chunks_overlapped: 5, hidden_nanos: 1000, wire_wait_nanos: 150 }
        );
        let earlier =
            OverlapStats { chunks_overlapped: 4, hidden_nanos: 900, wire_wait_nanos: 100 };
        assert_eq!(
            a.saturating_diff(&earlier),
            OverlapStats { chunks_overlapped: 1, hidden_nanos: 100, wire_wait_nanos: 50 }
        );
        // clamped, never negative
        assert!(earlier.saturating_diff(&a).is_zero());
    }

    #[test]
    fn skew_stats_merge_and_diff() {
        let mut a = SkewStats::default();
        assert!(a.is_zero());
        a.merge(&SkewStats {
            hot_keys: 2,
            rows_rerouted: 100,
            ratio_before_milli: 2600,
            ratio_after_milli: 1300,
        });
        a.merge(&SkewStats {
            hot_keys: 1,
            rows_rerouted: 50,
            ratio_before_milli: 1800,
            ratio_after_milli: 1400,
        });
        // counters sum, ratios keep the worst observation
        assert_eq!(a.hot_keys, 3);
        assert_eq!(a.rows_rerouted, 150);
        assert_eq!(a.ratio_before_milli, 2600);
        assert_eq!(a.ratio_after_milli, 1400);
        let earlier = SkewStats {
            hot_keys: 2,
            rows_rerouted: 100,
            ratio_before_milli: 2600,
            ratio_after_milli: 1300,
        };
        let d = a.saturating_diff(&earlier);
        assert_eq!(d.hot_keys, 1);
        assert_eq!(d.rows_rerouted, 50);
        // stage engaged skew handling: latest ratios carried through
        assert_eq!(d.ratio_before_milli, 2600);
        // no counter delta → ratios zeroed, not attributed to the stage
        assert!(a.saturating_diff(&a).is_zero());
        assert!(earlier.saturating_diff(&a).is_zero());
    }

    #[test]
    fn skew_stats_observe_keeps_latest_ratios_for_stage_attribution() {
        // worker-style accumulation: two exchanges, the second milder
        let mut running = SkewStats::default();
        running.observe(&SkewStats {
            hot_keys: 1,
            rows_rerouted: 100,
            ratio_before_milli: 4000,
            ratio_after_milli: 1400,
        });
        let cut = running; // stage boundary snapshot
        running.observe(&SkewStats {
            hot_keys: 1,
            rows_rerouted: 40,
            ratio_before_milli: 1200,
            ratio_after_milli: 1100,
        });
        // the second stage's diff must report ITS exchange, not the
        // run-wide worst
        let stage2 = running.saturating_diff(&cut);
        assert_eq!(stage2.rows_rerouted, 40);
        assert_eq!(stage2.ratio_before_milli, 1200);
        assert_eq!(stage2.ratio_after_milli, 1100);
    }

    #[test]
    fn local_stats_merge_and_diff() {
        let mut a = LocalStats::default();
        assert!(a.is_zero());
        a.merge(&LocalStats { morsels: 8, busy_nanos: 900, idle_nanos: 100 });
        a.merge(&LocalStats { morsels: 2, busy_nanos: 100, idle_nanos: 50 });
        assert_eq!(a, LocalStats { morsels: 10, busy_nanos: 1000, idle_nanos: 150 });
        let earlier = LocalStats { morsels: 8, busy_nanos: 900, idle_nanos: 100 };
        assert_eq!(
            a.saturating_diff(&earlier),
            LocalStats { morsels: 2, busy_nanos: 100, idle_nanos: 50 }
        );
        // clamped, never negative
        assert!(earlier.saturating_diff(&a).is_zero());
    }

    #[test]
    fn metrics_snapshot_diff_and_json() {
        let mut now = MetricsSnapshot::default();
        now.timers.add(Phase::Compute, Duration::from_nanos(500));
        now.spill = SpillStats { spilled_bytes: 128, spill_count: 2 };
        now.counters = vec![("bytes_sent".into(), 100), ("frames".into(), 7)];
        let mut earlier = MetricsSnapshot::default();
        earlier.counters = vec![("bytes_sent".into(), 40)];
        let d = now.saturating_diff(&earlier);
        assert_eq!(d.counter("bytes_sent"), 60);
        assert_eq!(d.counter("frames"), 7, "counter absent earlier diffs against 0");
        assert_eq!(d.counter("missing"), 0);
        assert_eq!(d.spill.spilled_bytes, 128);
        let json = now.to_json();
        assert!(json.contains("\"compute_ns\": 500"));
        assert!(json.contains("\"spilled_bytes\": 128"));
        assert!(json.contains("\"counters\": {\"bytes_sent\": 100, \"frames\": 7}"));
        assert!(now.summary().contains("spilled=128B"));
    }

    #[test]
    fn time_closure() {
        let mut t = PhaseTimers::new();
        let v = t.time(Phase::Auxiliary, || 42);
        assert_eq!(v, 42);
        assert!(t.get(Phase::Auxiliary) > Duration::ZERO);
    }

    fn sample_snapshot() -> MetricsSnapshot {
        let mut s = MetricsSnapshot::default();
        s.timers.add(Phase::Compute, Duration::from_nanos(500));
        s.timers.add(Phase::Communication, Duration::from_nanos(900));
        s.spill = SpillStats { spilled_bytes: 128, spill_count: 2 };
        s.skew = SkewStats {
            hot_keys: 1,
            rows_rerouted: 40,
            ratio_before_milli: 2600,
            ratio_after_milli: 1300,
        };
        s.overlap = OverlapStats { chunks_overlapped: 3, hidden_nanos: 700, wire_wait_nanos: 90 };
        s.local = LocalStats { morsels: 10, busy_nanos: 5000, idle_nanos: 400 };
        s.counters = vec![("bytes_sent".into(), 4096), ("rows_out".into(), 77)];
        s.hists.record("collective_ns", 800);
        s.hists.record("collective_ns", 1300);
        s.hists.record("spill_write_bytes", 0);
        s.hists.record("spill_write_bytes", u64::MAX);
        s
    }

    #[test]
    fn metrics_snapshot_json_round_trips() {
        let s = sample_snapshot();
        let back = MetricsSnapshot::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
        // empty snapshot round-trips too (timers absent vs zero are equal
        // under the semantic PhaseTimers equality)
        let empty = MetricsSnapshot::default();
        assert_eq!(MetricsSnapshot::from_json(&empty.to_json()).unwrap(), empty);
        // malformed input errors, never panics
        assert!(MetricsSnapshot::from_json("").is_err());
        assert!(MetricsSnapshot::from_json("{\"compute_ns\": }").is_err());
        assert!(
            MetricsSnapshot::from_json(
                "{\"hists\": {\"x\": {\"count\": 1, \"sum\": 1, \"buckets\": {\"99\": 1}}}}"
            )
            .is_err(),
            "out-of-range bucket index rejected"
        );
    }

    #[test]
    fn cluster_summary_equals_manual_merge() {
        let a = sample_snapshot();
        let mut b = sample_snapshot();
        b.counters.push(("only_b".into(), 5));
        b.hists.record("stage_duration_ns", 123456);
        let c = MetricsSnapshot::default();
        let summary = cluster_summary(&[a.clone(), b.clone(), c.clone()]);
        assert_eq!(summary.ranks, 3);
        let mut manual = a;
        manual.merge(&b);
        manual.merge(&c);
        assert_eq!(summary.merged, manual);
        // counters summed, histograms merged bucket-wise
        assert_eq!(summary.merged.counter("bytes_sent"), 8192);
        assert_eq!(summary.merged.counter("only_b"), 5);
        assert_eq!(summary.merged.hists.get("collective_ns").unwrap().count(), 4);
        let table = summary.table();
        assert!(table.contains("cluster summary (3 ranks)"));
        assert!(table.contains("bytes_sent"));
        let prom = summary.prometheus();
        assert!(prom.contains("cylonflow_ranks 3"));
        assert!(prom.contains("cylonflow_counter{name=\"bytes_sent\"} 8192"));
        assert!(prom.contains("le=\"+Inf\"} 4"));
    }

    #[test]
    fn stats_hub_accumulates_every_family() {
        let hub = StatsHub::new();
        hub.add_phase(Phase::Compute, Duration::from_millis(3));
        hub.record_spill(SpillStats { spilled_bytes: 64, spill_count: 1 });
        hub.record_overlap(OverlapStats {
            chunks_overlapped: 2,
            hidden_nanos: 10,
            wire_wait_nanos: 5,
        });
        hub.observe_skew(&SkewStats {
            hot_keys: 1,
            rows_rerouted: 9,
            ratio_before_milli: 2000,
            ratio_after_milli: 1100,
        });
        hub.record_hist("collective_ns", 700);
        hub.bump_counter("rows_out", 3);
        hub.bump_counter("rows_out", 4);
        hub.set_counter_max("peak", 9);
        hub.set_counter_max("peak", 2);
        hub.set_stage("join");
        assert_eq!(hub.peek_timers().get(Phase::Compute), Duration::from_millis(3));
        assert_eq!(hub.peek_spill().spilled_bytes, 64);
        assert_eq!(hub.peek_overlap().chunks_overlapped, 2);
        assert_eq!(hub.peek_skew().rows_rerouted, 9);
        assert_eq!(hub.peek_hists().get("collective_ns").unwrap().count(), 1);
        assert_eq!(hub.counters(), vec![("peak".to_string(), 9), ("rows_out".to_string(), 7)]);
        assert_eq!(hub.current_stage(), "join");
        // take_* resets, peek_* does not
        assert_eq!(hub.take_spill().spilled_bytes, 64);
        assert!(hub.peek_spill().is_zero());
        assert_eq!(hub.take_timers().get(Phase::Compute), Duration::from_millis(3));
        assert_eq!(hub.peek_timers().total(), Duration::ZERO);
        assert_eq!(hub.take_overlap().chunks_overlapped, 2);
        assert!(hub.peek_overlap().is_zero());
    }

    #[test]
    fn stats_hub_counters_survive_concurrent_bumps() {
        use std::sync::Arc;
        let hub = Arc::new(StatsHub::new());
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let hub = Arc::clone(&hub);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        hub.bump_counter("shared", 1);
                        hub.record_hist("shared_ns", 1);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(hub.counters(), vec![("shared".to_string(), 4000)]);
        assert_eq!(hub.peek_hists().get("shared_ns").unwrap().count(), 4000);
    }
}

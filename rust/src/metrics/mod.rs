//! Phase metrics — what Fig 6 (communication vs computation breakdown) is
//! made of.
//!
//! Each worker tracks wall time per [`Phase`]; the driver aggregates
//! per-rank reports into a [`Breakdown`].

use crate::util::Stopwatch;
use std::collections::BTreeMap;
use std::time::Duration;

/// The phases distributed operators are decomposed into (paper §III-B:
/// core local operator, auxiliary local operators, communication operators).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    /// Core local compute (local join/groupby/sort kernels).
    Compute,
    /// Auxiliary local work (hash partitioning, split/gather, serde).
    Auxiliary,
    /// Communication (collective routines on the wire / channel).
    Communication,
}

impl Phase {
    /// Stable label for reports.
    pub fn label(&self) -> &'static str {
        match self {
            Phase::Compute => "compute",
            Phase::Auxiliary => "auxiliary",
            Phase::Communication => "communication",
        }
    }
}

/// Per-worker phase timer. Cheap to clone into reports.
#[derive(Debug, Default, Clone)]
pub struct PhaseTimers {
    timers: BTreeMap<Phase, Duration>,
}

impl PhaseTimers {
    /// Fresh, all-zero timers.
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f` under `phase`.
    pub fn time<T>(&mut self, phase: Phase, f: impl FnOnce() -> T) -> T {
        let mut sw = Stopwatch::new();
        let out = sw.time(f);
        self.add(phase, sw.elapsed());
        out
    }

    /// Add a pre-measured duration to `phase`.
    pub fn add(&mut self, phase: Phase, d: Duration) {
        *self.timers.entry(phase).or_default() += d;
    }

    /// Accumulated duration for `phase`.
    pub fn get(&self, phase: Phase) -> Duration {
        self.timers.get(&phase).copied().unwrap_or_default()
    }

    /// Total across phases.
    pub fn total(&self) -> Duration {
        self.timers.values().sum()
    }

    /// Merge another report into this one (sums).
    pub fn merge(&mut self, other: &PhaseTimers) {
        for (p, d) in &other.timers {
            *self.timers.entry(*p).or_default() += *d;
        }
    }

    /// Reset all timers to zero.
    pub fn reset(&mut self) {
        self.timers.clear();
    }

    /// Per-phase `self − earlier`, clamped at zero — used to attribute a
    /// monotonically accumulating timer snapshot to one pipeline stage.
    pub fn saturating_diff(&self, earlier: &PhaseTimers) -> PhaseTimers {
        let mut out = PhaseTimers::new();
        for (p, d) in &self.timers {
            let before = earlier.get(*p);
            if *d > before {
                out.add(*p, *d - before);
            }
        }
        out
    }
}

/// Out-of-core exchange counters: how much shuffle/allgather payload
/// overflowed the in-memory budget onto disk (see
/// [`crate::store::SpillBuffer`]). Like [`PhaseTimers`] these accumulate
/// monotonically per worker and are attributed to stages by diffing
/// snapshots.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct SpillStats {
    /// Frame bytes written to spill files.
    pub spilled_bytes: u64,
    /// Number of frames that overflowed to disk.
    pub spill_count: u64,
}

impl SpillStats {
    /// True when nothing spilled.
    pub fn is_zero(&self) -> bool {
        self.spilled_bytes == 0 && self.spill_count == 0
    }

    /// Sum another snapshot into this one.
    pub fn merge(&mut self, other: &SpillStats) {
        self.spilled_bytes += other.spilled_bytes;
        self.spill_count += other.spill_count;
    }

    /// Per-counter `self − earlier`, clamped at zero — attributes a
    /// monotonically accumulating snapshot to one stage, exactly like
    /// [`PhaseTimers::saturating_diff`].
    pub fn saturating_diff(&self, earlier: &SpillStats) -> SpillStats {
        SpillStats {
            spilled_bytes: self.spilled_bytes.saturating_sub(earlier.spilled_bytes),
            spill_count: self.spill_count.saturating_sub(earlier.spill_count),
        }
    }
}

/// Overlapped-exchange counters (see [`crate::comm::nb`] and
/// [`crate::comm::algorithms::all_to_all_overlapped`]): how much of an
/// exchange's compute ran while wire requests were in flight — the
/// communication/computation overlap the double-buffered path exists to
/// create. Like [`SpillStats`] these accumulate monotonically per worker
/// and are attributed to stages by diffing snapshots. All zero when the
/// overlap path is disabled (the default).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct OverlapStats {
    /// Frames encoded or delivered to the spill sink while the wire was
    /// demonstrably active — a submitted send not yet reaped, or an
    /// arrived frame awaiting decode. These are the chunks whose compute
    /// the blocking path would have serialized against the wire.
    /// (A merely-posted, unmatched receive does not count, so the number
    /// stays zero when there is genuinely nothing to overlap.)
    pub chunks_overlapped: u64,
    /// Nanoseconds of encode/decode/spill work performed while the wire
    /// was busy (same definition as `chunks_overlapped`): wire-idle time
    /// the overlap hid under compute.
    pub hidden_nanos: u64,
    /// Nanoseconds spent submitting, reaping and *blocking on* wire
    /// requests: compute-idle time the overlap could not hide. With
    /// perfect overlap this approaches the bare submission overhead.
    pub wire_wait_nanos: u64,
}

impl OverlapStats {
    /// True when no overlapped exchange ran.
    pub fn is_zero(&self) -> bool {
        *self == OverlapStats::default()
    }

    /// Sum another snapshot into this one.
    pub fn merge(&mut self, other: &OverlapStats) {
        self.chunks_overlapped += other.chunks_overlapped;
        self.hidden_nanos += other.hidden_nanos;
        self.wire_wait_nanos += other.wire_wait_nanos;
    }

    /// Per-counter `self − earlier`, clamped at zero — attributes a
    /// monotonically accumulating snapshot to one stage, exactly like
    /// [`SpillStats::saturating_diff`].
    pub fn saturating_diff(&self, earlier: &OverlapStats) -> OverlapStats {
        OverlapStats {
            chunks_overlapped: self.chunks_overlapped.saturating_sub(earlier.chunks_overlapped),
            hidden_nanos: self.hidden_nanos.saturating_sub(earlier.hidden_nanos),
            wire_wait_nanos: self.wire_wait_nanos.saturating_sub(earlier.wire_wait_nanos),
        }
    }
}

/// Skew-aware repartitioning counters (see [`crate::dist::skew`]): what
/// the hot-key detector found and how much the split-assignment plan
/// moved. Like [`SpillStats`] these accumulate monotonically per worker
/// ([`crate::executor::CylonEnv::record_skew`]) and are attributed to
/// stages by diffing snapshots.
///
/// The ratio fields hold the **max/mean partition row ratio** of the
/// exchange, `×1000` (so they stay integer, `Eq` and diff-able): `1000`
/// means perfectly balanced, `4000` means the fullest rank received 4×
/// the mean. `_before` simulates the plain `hash mod p` routing of the
/// same rows; `_after` is the routing the skew plan actually performed.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct SkewStats {
    /// Distinct hot key-hash groups the estimator flagged.
    pub hot_keys: u64,
    /// Rows routed by the split-assignment (salted/replicated) path
    /// instead of plain `hash mod p`.
    pub rows_rerouted: u64,
    /// Max/mean partition row ratio under plain hashing, ×1000.
    pub ratio_before_milli: u64,
    /// Max/mean partition row ratio under the skew plan, ×1000.
    pub ratio_after_milli: u64,
}

impl SkewStats {
    /// True when no skew handling engaged.
    pub fn is_zero(&self) -> bool {
        *self == SkewStats::default()
    }

    /// Fold another snapshot in for *aggregation* (across ranks or
    /// stages): counters sum, ratios keep the worst (max) observation —
    /// "how bad did it get before/after".
    pub fn merge(&mut self, other: &SkewStats) {
        self.hot_keys += other.hot_keys;
        self.rows_rerouted += other.rows_rerouted;
        self.ratio_before_milli = self.ratio_before_milli.max(other.ratio_before_milli);
        self.ratio_after_milli = self.ratio_after_milli.max(other.ratio_after_milli);
    }

    /// Fold one exchange's counters into a worker's *running* stats
    /// ([`crate::executor::CylonEnv::record_skew`]): counters sum, but
    /// the ratio fields take the **latest** observation, so a stage
    /// snapshot diff reports the ratios of that stage's own exchange
    /// rather than the worst seen anywhere in the run.
    pub fn observe(&mut self, obs: &SkewStats) {
        self.hot_keys += obs.hot_keys;
        self.rows_rerouted += obs.rows_rerouted;
        self.ratio_before_milli = obs.ratio_before_milli;
        self.ratio_after_milli = obs.ratio_after_milli;
    }

    /// Attribute a monotonic snapshot to one stage: counters subtract
    /// (clamped); the ratio fields are carried from `self` only when the
    /// stage actually engaged skew handling (counter delta non-zero) —
    /// with [`SkewStats::observe`] accumulation they then hold the
    /// stage's own most recent exchange, since ratios are per-exchange
    /// observations, not running sums.
    pub fn saturating_diff(&self, earlier: &SkewStats) -> SkewStats {
        let hot_keys = self.hot_keys.saturating_sub(earlier.hot_keys);
        let rows_rerouted = self.rows_rerouted.saturating_sub(earlier.rows_rerouted);
        if hot_keys == 0 && rows_rerouted == 0 {
            return SkewStats::default();
        }
        SkewStats {
            hot_keys,
            rows_rerouted,
            ratio_before_milli: self.ratio_before_milli,
            ratio_after_milli: self.ratio_after_milli,
        }
    }
}

/// Morsel-executor counters (see [`crate::executor::MorselPool`] and
/// DESIGN.md §11): how much work the intra-rank worker pool ran and how
/// well it kept its workers fed. Like [`SpillStats`] these accumulate
/// monotonically per worker and are attributed to stages by diffing
/// snapshots. All zero when the pool is disabled (the default) — the
/// serial path never touches them.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct LocalStats {
    /// Morsels (parallel task units) executed by the pool.
    pub morsels: u64,
    /// Nanoseconds pool workers spent running morsel bodies, summed
    /// across workers (can exceed wall time — that is the point).
    pub busy_nanos: u64,
    /// Nanoseconds pool workers spent idle inside parallel regions
    /// (region wall × workers − busy): scheduling overhead plus
    /// tail-of-region starvation.
    pub idle_nanos: u64,
}

impl LocalStats {
    /// True when the pool ran nothing.
    pub fn is_zero(&self) -> bool {
        *self == LocalStats::default()
    }

    /// Sum another snapshot into this one.
    pub fn merge(&mut self, other: &LocalStats) {
        self.morsels += other.morsels;
        self.busy_nanos += other.busy_nanos;
        self.idle_nanos += other.idle_nanos;
    }

    /// Per-counter `self − earlier`, clamped at zero — attributes a
    /// monotonically accumulating snapshot to one stage, exactly like
    /// [`SpillStats::saturating_diff`].
    pub fn saturating_diff(&self, earlier: &LocalStats) -> LocalStats {
        LocalStats {
            morsels: self.morsels.saturating_sub(earlier.morsels),
            busy_nanos: self.busy_nanos.saturating_sub(earlier.busy_nanos),
            idle_nanos: self.idle_nanos.saturating_sub(earlier.idle_nanos),
        }
    }
}

/// Phase timers attributed to one pipeline/plan stage (delta of the
/// actor's monotonically accumulating timers across the stage,
/// communication included). Emitted per executed plan node by
/// [`crate::plan`]'s executor and surfaced through
/// [`crate::dist::pipeline()`]'s report.
#[derive(Debug, Clone)]
pub struct StageTiming {
    /// Stage label (`join`, `groupby`, `sort`, `add_scalar`, …).
    pub name: String,
    /// Compute / auxiliary / communication spent inside the stage.
    pub timers: PhaseTimers,
    /// Exchange bytes/frames this stage spilled to disk (zero below the
    /// memory budget).
    pub spill: SpillStats,
    /// Hot keys / rerouted rows the skew subsystem handled in this stage
    /// (zero when skew handling is disabled or found nothing).
    pub skew: SkewStats,
    /// Communication/computation overlap this stage's exchanges achieved
    /// (zero when the overlap path is disabled, the default).
    pub overlap: OverlapStats,
    /// Morsel-pool work this stage's local operators ran across cores
    /// (zero when intra-rank parallelism is disabled, the default).
    pub local: LocalStats,
}

/// One worker's unified metrics view at a point in time: every
/// monotonically accumulating counter family the runtime keeps (phase
/// timers, spill, skew, overlap) plus a free-form named-counter
/// registry, snapshotted together. This is what
/// [`crate::executor::CylonEnv::snapshot`] returns — the single
/// replacement for the former per-family accessors — and what the plan
/// executor diffs across stage boundaries.
#[derive(Debug, Default, Clone)]
pub struct MetricsSnapshot {
    /// Compute / auxiliary / communication wall time.
    pub timers: PhaseTimers,
    /// Out-of-core exchange counters.
    pub spill: SpillStats,
    /// Skew-aware repartitioning counters.
    pub skew: SkewStats,
    /// Communication/computation overlap counters.
    pub overlap: OverlapStats,
    /// Morsel-executor (intra-rank parallelism) counters.
    pub local: LocalStats,
    /// Named counters that don't belong to a structured family
    /// (`bytes_sent`, `trace_events_recorded`, …), sorted by name so the
    /// JSON emit is deterministic.
    pub counters: Vec<(String, u64)>,
}

impl MetricsSnapshot {
    /// Look up a named counter (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| *v)
            .unwrap_or(0)
    }

    /// Attribute the window between two snapshots: every family diffs
    /// with its own `saturating_diff` rules; named counters are matched
    /// by name and clamped at zero (a counter absent from `earlier`
    /// diffs against 0).
    pub fn saturating_diff(&self, earlier: &MetricsSnapshot) -> MetricsSnapshot {
        MetricsSnapshot {
            timers: self.timers.saturating_diff(&earlier.timers),
            spill: self.spill.saturating_diff(&earlier.spill),
            skew: self.skew.saturating_diff(&earlier.skew),
            overlap: self.overlap.saturating_diff(&earlier.overlap),
            local: self.local.saturating_diff(&earlier.local),
            counters: self
                .counters
                .iter()
                .map(|(n, v)| (n.clone(), v.saturating_sub(earlier.counter(n))))
                .collect(),
        }
    }

    /// Machine-readable JSON object, hand-rolled in the same stable
    /// flat-key style as the bench records (every value an integer, keys
    /// never reordered):
    ///
    /// ```json
    /// {"compute_ns": 0, "auxiliary_ns": 0, "communication_ns": 0,
    ///  "spilled_bytes": 0, "spill_count": 0,
    ///  "hot_keys": 0, "rows_rerouted": 0,
    ///  "ratio_before_milli": 0, "ratio_after_milli": 0,
    ///  "chunks_overlapped": 0, "hidden_ns": 0, "wire_wait_ns": 0,
    ///  "local_morsels": 0, "local_busy_ns": 0, "local_idle_ns": 0,
    ///  "counters": {"bytes_sent": 0}}
    /// ```
    pub fn to_json(&self) -> String {
        let counters = self
            .counters
            .iter()
            .map(|(n, v)| format!("\"{n}\": {v}"))
            .collect::<Vec<_>>()
            .join(", ");
        format!(
            concat!(
                "{{\"compute_ns\": {}, \"auxiliary_ns\": {}, \"communication_ns\": {}, ",
                "\"spilled_bytes\": {}, \"spill_count\": {}, ",
                "\"hot_keys\": {}, \"rows_rerouted\": {}, ",
                "\"ratio_before_milli\": {}, \"ratio_after_milli\": {}, ",
                "\"chunks_overlapped\": {}, \"hidden_ns\": {}, \"wire_wait_ns\": {}, ",
                "\"local_morsels\": {}, \"local_busy_ns\": {}, \"local_idle_ns\": {}, ",
                "\"counters\": {{{}}}}}"
            ),
            self.timers.get(Phase::Compute).as_nanos(),
            self.timers.get(Phase::Auxiliary).as_nanos(),
            self.timers.get(Phase::Communication).as_nanos(),
            self.spill.spilled_bytes,
            self.spill.spill_count,
            self.skew.hot_keys,
            self.skew.rows_rerouted,
            self.skew.ratio_before_milli,
            self.skew.ratio_after_milli,
            self.overlap.chunks_overlapped,
            self.overlap.hidden_nanos,
            self.overlap.wire_wait_nanos,
            self.local.morsels,
            self.local.busy_nanos,
            self.local.idle_nanos,
            counters,
        )
    }

    /// One-line human summary (what the examples print at exit).
    pub fn summary(&self) -> String {
        format!(
            "metrics: compute={:.1}ms auxiliary={:.1}ms communication={:.1}ms \
             spilled={}B skew_rerouted={} overlapped={} morsels={} bytes_sent={}",
            self.timers.get(Phase::Compute).as_secs_f64() * 1e3,
            self.timers.get(Phase::Auxiliary).as_secs_f64() * 1e3,
            self.timers.get(Phase::Communication).as_secs_f64() * 1e3,
            self.spill.spilled_bytes,
            self.skew.rows_rerouted,
            self.overlap.chunks_overlapped,
            self.local.morsels,
            self.counter("bytes_sent"),
        )
    }
}

/// Aggregated comm/compute breakdown across a gang of workers.
#[derive(Debug, Clone)]
pub struct Breakdown {
    /// Per-rank timer snapshots.
    pub per_rank: Vec<PhaseTimers>,
}

impl Breakdown {
    /// Build from per-rank snapshots.
    pub fn new(per_rank: Vec<PhaseTimers>) -> Self {
        Breakdown { per_rank }
    }

    /// Mean duration of `phase` across ranks.
    pub fn mean(&self, phase: Phase) -> Duration {
        if self.per_rank.is_empty() {
            return Duration::ZERO;
        }
        let sum: Duration = self.per_rank.iter().map(|t| t.get(phase)).sum();
        sum / self.per_rank.len() as u32
    }

    /// Max duration of `phase` across ranks (the BSP critical path).
    pub fn max(&self, phase: Phase) -> Duration {
        self.per_rank
            .iter()
            .map(|t| t.get(phase))
            .max()
            .unwrap_or_default()
    }

    /// Fraction of mean wall time spent in communication — the Fig 6 y-axis.
    pub fn comm_fraction(&self) -> f64 {
        let comm = self.mean(Phase::Communication).as_secs_f64();
        let total: f64 = [Phase::Compute, Phase::Auxiliary, Phase::Communication]
            .iter()
            .map(|p| self.mean(*p).as_secs_f64())
            .sum();
        if total == 0.0 {
            0.0
        } else {
            comm / total
        }
    }

    /// One-line report: `compute=…ms auxiliary=…ms communication=…ms (x%)`.
    pub fn report(&self) -> String {
        format!(
            "compute={:.1}ms auxiliary={:.1}ms communication={:.1}ms (comm {:.0}%)",
            self.mean(Phase::Compute).as_secs_f64() * 1e3,
            self.mean(Phase::Auxiliary).as_secs_f64() * 1e3,
            self.mean(Phase::Communication).as_secs_f64() * 1e3,
            self.comm_fraction() * 100.0
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timers_accumulate_and_merge() {
        let mut t = PhaseTimers::new();
        t.add(Phase::Compute, Duration::from_millis(10));
        t.add(Phase::Compute, Duration::from_millis(5));
        t.add(Phase::Communication, Duration::from_millis(15));
        assert_eq!(t.get(Phase::Compute), Duration::from_millis(15));
        let mut u = PhaseTimers::new();
        u.merge(&t);
        u.merge(&t);
        assert_eq!(u.total(), Duration::from_millis(60));
    }

    #[test]
    fn breakdown_fractions() {
        let mut a = PhaseTimers::new();
        a.add(Phase::Compute, Duration::from_millis(30));
        a.add(Phase::Communication, Duration::from_millis(10));
        let b = a.clone();
        let br = Breakdown::new(vec![a, b]);
        assert!((br.comm_fraction() - 0.25).abs() < 1e-9);
        assert_eq!(br.max(Phase::Compute), Duration::from_millis(30));
        assert!(br.report().contains("comm 25%"));
    }

    #[test]
    fn saturating_diff_attributes_deltas() {
        let mut before = PhaseTimers::new();
        before.add(Phase::Compute, Duration::from_millis(10));
        before.add(Phase::Communication, Duration::from_millis(4));
        let mut after = before.clone();
        after.add(Phase::Compute, Duration::from_millis(5));
        after.add(Phase::Auxiliary, Duration::from_millis(2));
        let d = after.saturating_diff(&before);
        assert_eq!(d.get(Phase::Compute), Duration::from_millis(5));
        assert_eq!(d.get(Phase::Auxiliary), Duration::from_millis(2));
        assert_eq!(d.get(Phase::Communication), Duration::ZERO);
        // clamped: diff against a later snapshot is zero, not negative
        assert_eq!(before.saturating_diff(&after).total(), Duration::ZERO);
    }

    #[test]
    fn spill_stats_merge_and_diff() {
        let mut a = SpillStats::default();
        assert!(a.is_zero());
        a.merge(&SpillStats { spilled_bytes: 100, spill_count: 2 });
        a.merge(&SpillStats { spilled_bytes: 50, spill_count: 1 });
        assert_eq!(a, SpillStats { spilled_bytes: 150, spill_count: 3 });
        let earlier = SpillStats { spilled_bytes: 100, spill_count: 2 };
        assert_eq!(
            a.saturating_diff(&earlier),
            SpillStats { spilled_bytes: 50, spill_count: 1 }
        );
        // clamped, never negative
        assert!(earlier.saturating_diff(&a).is_zero());
    }

    #[test]
    fn overlap_stats_merge_and_diff() {
        let mut a = OverlapStats::default();
        assert!(a.is_zero());
        a.merge(&OverlapStats { chunks_overlapped: 4, hidden_nanos: 900, wire_wait_nanos: 100 });
        a.merge(&OverlapStats { chunks_overlapped: 1, hidden_nanos: 100, wire_wait_nanos: 50 });
        assert_eq!(
            a,
            OverlapStats { chunks_overlapped: 5, hidden_nanos: 1000, wire_wait_nanos: 150 }
        );
        let earlier =
            OverlapStats { chunks_overlapped: 4, hidden_nanos: 900, wire_wait_nanos: 100 };
        assert_eq!(
            a.saturating_diff(&earlier),
            OverlapStats { chunks_overlapped: 1, hidden_nanos: 100, wire_wait_nanos: 50 }
        );
        // clamped, never negative
        assert!(earlier.saturating_diff(&a).is_zero());
    }

    #[test]
    fn skew_stats_merge_and_diff() {
        let mut a = SkewStats::default();
        assert!(a.is_zero());
        a.merge(&SkewStats {
            hot_keys: 2,
            rows_rerouted: 100,
            ratio_before_milli: 2600,
            ratio_after_milli: 1300,
        });
        a.merge(&SkewStats {
            hot_keys: 1,
            rows_rerouted: 50,
            ratio_before_milli: 1800,
            ratio_after_milli: 1400,
        });
        // counters sum, ratios keep the worst observation
        assert_eq!(a.hot_keys, 3);
        assert_eq!(a.rows_rerouted, 150);
        assert_eq!(a.ratio_before_milli, 2600);
        assert_eq!(a.ratio_after_milli, 1400);
        let earlier = SkewStats {
            hot_keys: 2,
            rows_rerouted: 100,
            ratio_before_milli: 2600,
            ratio_after_milli: 1300,
        };
        let d = a.saturating_diff(&earlier);
        assert_eq!(d.hot_keys, 1);
        assert_eq!(d.rows_rerouted, 50);
        // stage engaged skew handling: latest ratios carried through
        assert_eq!(d.ratio_before_milli, 2600);
        // no counter delta → ratios zeroed, not attributed to the stage
        assert!(a.saturating_diff(&a).is_zero());
        assert!(earlier.saturating_diff(&a).is_zero());
    }

    #[test]
    fn skew_stats_observe_keeps_latest_ratios_for_stage_attribution() {
        // worker-style accumulation: two exchanges, the second milder
        let mut running = SkewStats::default();
        running.observe(&SkewStats {
            hot_keys: 1,
            rows_rerouted: 100,
            ratio_before_milli: 4000,
            ratio_after_milli: 1400,
        });
        let cut = running; // stage boundary snapshot
        running.observe(&SkewStats {
            hot_keys: 1,
            rows_rerouted: 40,
            ratio_before_milli: 1200,
            ratio_after_milli: 1100,
        });
        // the second stage's diff must report ITS exchange, not the
        // run-wide worst
        let stage2 = running.saturating_diff(&cut);
        assert_eq!(stage2.rows_rerouted, 40);
        assert_eq!(stage2.ratio_before_milli, 1200);
        assert_eq!(stage2.ratio_after_milli, 1100);
    }

    #[test]
    fn local_stats_merge_and_diff() {
        let mut a = LocalStats::default();
        assert!(a.is_zero());
        a.merge(&LocalStats { morsels: 8, busy_nanos: 900, idle_nanos: 100 });
        a.merge(&LocalStats { morsels: 2, busy_nanos: 100, idle_nanos: 50 });
        assert_eq!(a, LocalStats { morsels: 10, busy_nanos: 1000, idle_nanos: 150 });
        let earlier = LocalStats { morsels: 8, busy_nanos: 900, idle_nanos: 100 };
        assert_eq!(
            a.saturating_diff(&earlier),
            LocalStats { morsels: 2, busy_nanos: 100, idle_nanos: 50 }
        );
        // clamped, never negative
        assert!(earlier.saturating_diff(&a).is_zero());
    }

    #[test]
    fn metrics_snapshot_diff_and_json() {
        let mut now = MetricsSnapshot::default();
        now.timers.add(Phase::Compute, Duration::from_nanos(500));
        now.spill = SpillStats { spilled_bytes: 128, spill_count: 2 };
        now.counters = vec![("bytes_sent".into(), 100), ("frames".into(), 7)];
        let mut earlier = MetricsSnapshot::default();
        earlier.counters = vec![("bytes_sent".into(), 40)];
        let d = now.saturating_diff(&earlier);
        assert_eq!(d.counter("bytes_sent"), 60);
        assert_eq!(d.counter("frames"), 7, "counter absent earlier diffs against 0");
        assert_eq!(d.counter("missing"), 0);
        assert_eq!(d.spill.spilled_bytes, 128);
        let json = now.to_json();
        assert!(json.contains("\"compute_ns\": 500"));
        assert!(json.contains("\"spilled_bytes\": 128"));
        assert!(json.contains("\"counters\": {\"bytes_sent\": 100, \"frames\": 7}"));
        assert!(now.summary().contains("spilled=128B"));
    }

    #[test]
    fn time_closure() {
        let mut t = PhaseTimers::new();
        let v = t.time(Phase::Auxiliary, || 42);
        assert_eq!(v, 42);
        assert!(t.get(Phase::Auxiliary) > Duration::ZERO);
    }
}

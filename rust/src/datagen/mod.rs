//! Workload generation — the paper's benchmark datasets.
//!
//! The paper uses uniformly random data, two int64 columns, 10⁹ rows,
//! **cardinality 90 %** (fraction of unique keys — the worst case for
//! key-based operators). We reproduce that generator, seeded and scaled,
//! plus a Zipf-ish skewed generator for the load-imbalance ablation.

use crate::column::Column;
use crate::table::Table;
use crate::util::SplitMix64;

/// The paper's benchmark table: two int64 columns `(k, v)`, `rows` rows,
/// keys uniform over a domain sized so that the expected fraction of
/// distinct keys ≈ `cardinality` (0 < cardinality ≤ 1).
pub fn uniform_table(seed: u64, rows: usize, cardinality: f64) -> Table {
    assert!((0.0..=1.0).contains(&cardinality) && cardinality > 0.0);
    let domain = ((rows as f64 * cardinality).ceil() as u64).max(1);
    let mut rng = SplitMix64::new(seed);
    let keys: Vec<i64> = (0..rows).map(|_| rng.next_bounded(domain) as i64).collect();
    // Values bounded to 1e6: realistic payload domain, keeps i64 sums
    // far from overflow and f64 aggregate accumulation exact.
    let vals: Vec<i64> = (0..rows).map(|_| rng.next_bounded(1_000_000) as i64).collect();
    Table::from_columns(vec![
        ("k", Column::from_i64(keys)),
        ("v", Column::from_i64(vals)),
    ])
    .expect("generated columns are well-formed")
}

/// Like [`uniform_table`] but with an extra float64 value column (for
/// aggregate benchmarks that need a numeric payload).
pub fn uniform_table_f64(seed: u64, rows: usize, cardinality: f64) -> Table {
    let base = uniform_table(seed, rows, cardinality);
    let mut rng = SplitMix64::new(seed ^ 0xf00d);
    let f: Vec<f64> = (0..rows).map(|_| rng.next_f64() * 1000.0).collect();
    base.with_column("w", Column::from_f64(f)).unwrap()
}

/// Skewed keys: a `hot_frac` fraction of rows all share one hot key, the
/// rest are uniform. Models the "skewed datasets could starve some
/// processes" scenario from the paper's §VI.
pub fn skewed_table(seed: u64, rows: usize, hot_frac: f64) -> Table {
    assert!((0.0..=1.0).contains(&hot_frac));
    let mut rng = SplitMix64::new(seed);
    let hot_key = 0i64;
    let keys: Vec<i64> = (0..rows)
        .map(|_| {
            if rng.next_f64() < hot_frac {
                hot_key
            } else {
                rng.next_bounded(rows as u64).max(1) as i64
            }
        })
        .collect();
    // Values bounded to 1e6: realistic payload domain, keeps i64 sums
    // far from overflow and f64 aggregate accumulation exact.
    let vals: Vec<i64> = (0..rows).map(|_| rng.next_bounded(1_000_000) as i64).collect();
    Table::from_columns(vec![
        ("k", Column::from_i64(keys)),
        ("v", Column::from_i64(vals)),
    ])
    .unwrap()
}

/// Zipf-distributed keys: key `k ∈ [0, n_keys)` is drawn with
/// probability `∝ (k + 1)^{-exponent}` via inverse-CDF sampling over the
/// precomputed cumulative weights. The workload of the skew-aware
/// repartitioning experiments (paper §VI load imbalance): at
/// `exponent = 1.2` over a small key domain, the top key alone holds an
/// outsized share of the rows.
pub fn zipf_table(seed: u64, rows: usize, exponent: f64, n_keys: usize) -> Table {
    assert!(n_keys >= 1, "zipf_table needs at least one key");
    assert!(exponent > 0.0 && exponent.is_finite());
    let cum = zipf_cumulative(exponent, n_keys);
    let total = *cum.last().expect("n_keys >= 1");
    let mut rng = SplitMix64::new(seed);
    let keys: Vec<i64> = (0..rows)
        .map(|_| zipf_draw(&cum, total, rng.next_f64()))
        .collect();
    // Values bounded to 1e6: realistic payload domain, keeps i64 sums
    // far from overflow and f64 aggregate accumulation exact.
    let vals: Vec<i64> = (0..rows).map(|_| rng.next_bounded(1_000_000) as i64).collect();
    Table::from_columns(vec![
        ("k", Column::from_i64(keys)),
        ("v", Column::from_i64(vals)),
    ])
    .unwrap()
}

/// The per-worker slice of a logical `total_rows` zipf dataset (the
/// skewed sibling of [`partition_for_rank`]): worker `rank` of `world`
/// draws its own rows from the *same* global key distribution, so hot
/// keys are hot on every partition and collide after a shuffle.
pub fn zipf_partition_for_rank(
    seed: u64,
    total_rows: usize,
    exponent: f64,
    n_keys: usize,
    rank: usize,
    world: usize,
) -> Table {
    let base = total_rows / world;
    let extra = total_rows % world;
    let rows = base + usize::from(rank < extra);
    zipf_table(seed ^ (rank as u64).wrapping_mul(0x9e37_79b9), rows, exponent, n_keys)
}

/// Cumulative (unnormalized) zipf weights: `cum[k] = Σ_{j≤k} (j+1)^-s`.
fn zipf_cumulative(exponent: f64, n_keys: usize) -> Vec<f64> {
    let mut cum = Vec::with_capacity(n_keys);
    let mut acc = 0.0;
    for k in 1..=n_keys {
        acc += (k as f64).powf(-exponent);
        cum.push(acc);
    }
    cum
}

/// Inverse-CDF draw: smallest key whose cumulative weight covers `u`.
fn zipf_draw(cum: &[f64], total: f64, u: f64) -> i64 {
    let target = u * total;
    let mut lo = 0usize;
    let mut hi = cum.len() - 1;
    while lo < hi {
        let mid = (lo + hi) / 2;
        if cum[mid] < target {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo as i64
}

/// The per-worker slice of a logical `total_rows` dataset: worker `rank` of
/// `world` generates its own partition locally (the paper loads partitions
/// directly on workers; generation stands in for Parquet reads).
pub fn partition_for_rank(
    seed: u64,
    total_rows: usize,
    cardinality: f64,
    rank: usize,
    world: usize,
) -> Table {
    let base = total_rows / world;
    let extra = total_rows % world;
    let rows = base + usize::from(rank < extra);
    // Mix the rank into the seed but keep the *key domain* global so joins
    // across partitions hit (same key space on every worker).
    let domain = ((total_rows as f64 * cardinality).ceil() as u64).max(1);
    let mut rng = SplitMix64::new(seed ^ (rank as u64).wrapping_mul(0x9e37_79b9));
    let keys: Vec<i64> = (0..rows).map(|_| rng.next_bounded(domain) as i64).collect();
    // Values bounded to 1e6: realistic payload domain, keeps i64 sums
    // far from overflow and f64 aggregate accumulation exact.
    let vals: Vec<i64> = (0..rows).map(|_| rng.next_bounded(1_000_000) as i64).collect();
    Table::from_columns(vec![
        ("k", Column::from_i64(keys)),
        ("v", Column::from_i64(vals)),
    ])
    .unwrap()
}

/// Count of distinct values in an i64 slice (test helper for cardinality).
pub fn distinct_count(xs: &[i64]) -> usize {
    let mut v = xs.to_vec();
    v.sort_unstable();
    v.dedup();
    v.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_sized() {
        let a = uniform_table(42, 1000, 0.9);
        let b = uniform_table(42, 1000, 0.9);
        assert_eq!(a, b);
        assert_eq!(a.num_rows(), 1000);
        assert_eq!(a.num_columns(), 2);
    }

    #[test]
    fn cardinality_approx() {
        let t = uniform_table(1, 100_000, 0.9);
        let d = distinct_count(t.column(0).unwrap().i64_values().unwrap());
        // E[distinct] for n draws over 0.9n domain ≈ 0.9n(1-e^{-1/0.9}) ≈ 0.60n;
        // just check it is "high cardinality" rather than exact.
        assert!(d > 50_000, "distinct {d}");
        let low = uniform_table(1, 100_000, 0.001);
        let dl = distinct_count(low.column(0).unwrap().i64_values().unwrap());
        assert!(dl <= 100, "distinct {dl}");
    }

    #[test]
    fn skew_concentrates() {
        let t = skewed_table(7, 10_000, 0.5);
        let keys = t.column(0).unwrap().i64_values().unwrap();
        let hot = keys.iter().filter(|&&k| k == 0).count();
        assert!((4_000..6_000).contains(&hot), "hot count {hot}");
    }

    #[test]
    fn zipf_shares_match_theory() {
        // zipf(1.2) over 4 keys: p(0) = 1/H ≈ 0.528 with
        // H = 1 + 2^-1.2 + 3^-1.2 + 4^-1.2 ≈ 1.892
        let n = 100_000;
        let t = zipf_table(42, n, 1.2, 4);
        assert_eq!(t.num_rows(), n);
        let keys = t.column(0).unwrap().i64_values().unwrap();
        assert!(keys.iter().all(|&k| (0..4).contains(&k)));
        let top = keys.iter().filter(|&&k| k == 0).count() as f64 / n as f64;
        assert!((0.50..0.56).contains(&top), "top-key share {top}");
        let second = keys.iter().filter(|&&k| k == 1).count() as f64 / n as f64;
        assert!((0.20..0.26).contains(&second), "second-key share {second}");
        // deterministic
        assert_eq!(zipf_table(42, 1000, 1.2, 4), zipf_table(42, 1000, 1.2, 4));
        // near-flat exponent ≈ near-uniform shares
        let flat = zipf_table(7, n, 0.01, 10);
        let k0 = flat
            .column(0)
            .unwrap()
            .i64_values()
            .unwrap()
            .iter()
            .filter(|&&k| k == 0)
            .count() as f64
            / n as f64;
        assert!((0.05..0.15).contains(&k0), "flat share {k0}");
    }

    #[test]
    fn zipf_rank_partitions_cover_total_and_share_hot_key() {
        let world = 4;
        let total = 2003;
        let mut rows = 0;
        for r in 0..world {
            let t = zipf_partition_for_rank(9, total, 1.2, 8, r, world);
            // the hot key shows up on every rank's partition
            assert!(t.column(0).unwrap().i64_values().unwrap().contains(&0), "rank {r}");
            rows += t.num_rows();
        }
        assert_eq!(rows, total);
    }

    #[test]
    fn rank_partitions_cover_total() {
        let world = 4;
        let total = 1003;
        let rows: usize = (0..world)
            .map(|r| partition_for_rank(5, total, 0.9, r, world).num_rows())
            .sum();
        assert_eq!(rows, total);
    }
}

//! The streaming pipeline: source thread → sharded bounded queues → stage
//! worker per shard → collected shard outputs.

use super::queue::BoundedQueue;
use super::source::Source;
use crate::error::{Error, Result};
use crate::ops::{partition_by_hash, KeyHasher, NativeHasher};
use crate::table::Table;
use std::sync::Arc;

/// A per-shard transformation applied to each incoming batch.
pub type StageFn = dyn Fn(Table) -> Result<Table> + Send + Sync;

/// One sharded stage: `shards` workers each own a bounded input queue.
pub struct ShardedStage {
    /// Shard count (stage parallelism).
    pub shards: usize,
    /// Input queue capacity per shard (batches) — the backpressure knob.
    pub queue_capacity: usize,
    /// Key columns for shard routing (hash of these picks the shard).
    pub key_cols: Vec<usize>,
    /// The transformation.
    pub f: Arc<StageFn>,
}

impl ShardedStage {
    /// Stage applying `f` on `shards` workers, routed by `key_cols`.
    pub fn new(
        shards: usize,
        queue_capacity: usize,
        key_cols: Vec<usize>,
        f: impl Fn(Table) -> Result<Table> + Send + Sync + 'static,
    ) -> Self {
        ShardedStage {
            shards,
            queue_capacity,
            key_cols,
            f: Arc::new(f),
        }
    }
}

/// Outcome of a pipeline run.
#[derive(Debug, Clone)]
pub struct StreamReport {
    /// Rows ingested from the source.
    pub rows_in: usize,
    /// Batches ingested.
    pub batches: usize,
    /// Rows emitted per shard (post-transform).
    pub rows_out_per_shard: Vec<usize>,
    /// Backpressure stalls per shard queue.
    pub stalls_per_shard: Vec<u64>,
    /// Observed high-water queue depth per shard.
    pub max_depth_per_shard: Vec<usize>,
    /// Output partitions (one per shard), concatenated batches.
    pub outputs: Vec<Table>,
}

/// A single-stage sharded streaming pipeline (multi-stage pipelines
/// compose by chaining runs; each run is one ingest pass).
pub struct StreamPipeline {
    stage: ShardedStage,
    hasher: Box<dyn KeyHasher>,
}

impl StreamPipeline {
    /// Pipeline with the native hasher for shard routing.
    pub fn new(stage: ShardedStage) -> Self {
        StreamPipeline { stage, hasher: Box::new(NativeHasher) }
    }

    /// Pipeline with an explicit hasher (PJRT path supported).
    pub fn with_hasher(stage: ShardedStage, hasher: Box<dyn KeyHasher>) -> Self {
        StreamPipeline { stage, hasher }
    }

    /// Drive `source` to exhaustion through the stage; blocks until all
    /// shards drain.
    pub fn run(&self, mut source: Box<dyn Source>) -> Result<StreamReport> {
        let shards = self.stage.shards;
        if shards == 0 {
            return Err(Error::invalid("pipeline needs at least one shard"));
        }
        let queues: Vec<Arc<BoundedQueue<Table>>> = (0..shards)
            .map(|_| Arc::new(BoundedQueue::new(self.stage.queue_capacity)))
            .collect();

        // shard workers
        let mut handles = Vec::with_capacity(shards);
        for q in &queues {
            let q = q.clone();
            let f = self.stage.f.clone();
            handles.push(std::thread::spawn(move || -> Result<Vec<Table>> {
                let mut out = Vec::new();
                while let Some(batch) = q.pop() {
                    out.push(f(batch)?);
                }
                Ok(out)
            }));
        }

        // ingest loop (the orchestrator thread): route each batch's rows
        // to shard queues by key hash — blocking pushes ARE the
        // backpressure.
        let mut rows_in = 0usize;
        let mut batches = 0usize;
        while let Some(batch) = source.next_batch() {
            rows_in += batch.num_rows();
            batches += 1;
            let parts =
                partition_by_hash(&batch, &self.stage.key_cols, shards, self.hasher.as_ref())?;
            for (shard, part) in parts.into_iter().enumerate() {
                if part.num_rows() > 0 && !queues[shard].push(part) {
                    return Err(Error::Executor("shard queue closed early".into()));
                }
            }
        }
        for q in &queues {
            q.close();
        }

        let mut outputs = Vec::with_capacity(shards);
        let mut rows_out = Vec::with_capacity(shards);
        for h in handles {
            let tables = h
                .join()
                .map_err(|_| Error::Executor("shard worker panicked".into()))??;
            let merged = if tables.is_empty() {
                None
            } else {
                Some(Table::concat(&tables.iter().collect::<Vec<_>>())?)
            };
            let rows = merged.as_ref().map(|t| t.num_rows()).unwrap_or(0);
            rows_out.push(rows);
            if let Some(t) = merged {
                outputs.push(t);
            }
        }
        Ok(StreamReport {
            rows_in,
            batches,
            rows_out_per_shard: rows_out,
            stalls_per_shard: queues.iter().map(|q| q.stalls()).collect(),
            max_depth_per_shard: queues.iter().map(|q| q.max_depth()).collect(),
            outputs,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::{self, AggFun, AggSpec};
    use crate::stream::source::GeneratorSource;

    #[test]
    fn identity_stage_conserves_rows() {
        let stage = ShardedStage::new(4, 8, vec![0], Ok);
        let p = StreamPipeline::new(stage);
        let rep = p
            .run(Box::new(GeneratorSource::new(1, 10_000, 512, 0.9)))
            .unwrap();
        assert_eq!(rep.rows_in, 10_000);
        assert_eq!(rep.rows_out_per_shard.iter().sum::<usize>(), 10_000);
        assert_eq!(rep.batches, 20);
    }

    #[test]
    fn shard_routing_is_key_consistent() {
        // each key must land on exactly one shard across ALL batches
        let stage = ShardedStage::new(3, 4, vec![0], Ok);
        let p = StreamPipeline::new(stage);
        let rep = p
            .run(Box::new(GeneratorSource::new(2, 5_000, 256, 0.05)))
            .unwrap();
        let mut owner = std::collections::HashMap::new();
        for (si, t) in rep.outputs.iter().enumerate() {
            for &k in t.column(0).unwrap().i64_values().unwrap() {
                let e = owner.entry(k).or_insert(si);
                assert_eq!(*e, si, "key {k} on two shards");
            }
        }
    }

    #[test]
    fn aggregating_stage_and_backpressure_counters() {
        // slow stage + tiny queues force backpressure stalls
        let stage = ShardedStage::new(2, 1, vec![0], |t| {
            std::thread::sleep(std::time::Duration::from_micros(300));
            ops::groupby(&t, &[0], &[AggSpec::new(1, AggFun::Sum)])
        });
        let p = StreamPipeline::new(stage);
        let rep = p
            .run(Box::new(GeneratorSource::new(3, 20_000, 128, 0.01)))
            .unwrap();
        assert!(rep.rows_in == 20_000);
        assert!(
            rep.stalls_per_shard.iter().sum::<u64>() > 0,
            "expected backpressure stalls: {rep:?}"
        );
        // low cardinality -> aggregated outputs are much smaller than input
        assert!(rep.rows_out_per_shard.iter().sum::<usize>() < 20_000);
    }

    #[test]
    fn zero_shards_rejected() {
        let stage = ShardedStage::new(0, 1, vec![0], Ok);
        let p = StreamPipeline::new(stage);
        assert!(p
            .run(Box::new(GeneratorSource::new(1, 10, 10, 0.9)))
            .is_err());
    }
}

//! Micro-batch sources.

use crate::table::Table;
use crate::util::SplitMix64;
use crate::column::Column;

/// A pull source of micro-batches.
pub trait Source: Send {
    /// Next batch, or `None` at end of stream.
    fn next_batch(&mut self) -> Option<Table>;
}

/// Synthetic source: `total_rows` of the paper's `(k, v)` schema in
/// batches of `batch_rows` (stands in for Kafka/file tailing).
pub struct GeneratorSource {
    remaining: usize,
    batch_rows: usize,
    cardinality_domain: u64,
    rng: SplitMix64,
}

impl GeneratorSource {
    /// New source; `cardinality` as in [`crate::datagen::uniform_table`].
    pub fn new(seed: u64, total_rows: usize, batch_rows: usize, cardinality: f64) -> Self {
        GeneratorSource {
            remaining: total_rows,
            batch_rows,
            cardinality_domain: ((total_rows as f64 * cardinality).ceil() as u64).max(1),
            rng: SplitMix64::new(seed),
        }
    }
}

impl Source for GeneratorSource {
    fn next_batch(&mut self) -> Option<Table> {
        if self.remaining == 0 {
            return None;
        }
        let n = self.batch_rows.min(self.remaining);
        self.remaining -= n;
        let keys: Vec<i64> = (0..n)
            .map(|_| self.rng.next_bounded(self.cardinality_domain) as i64)
            .collect();
        let vals: Vec<i64> = (0..n)
            .map(|_| self.rng.next_bounded(1_000_000) as i64)
            .collect();
        Some(
            Table::from_columns(vec![
                ("k", Column::from_i64(keys)),
                ("v", Column::from_i64(vals)),
            ])
            .expect("well-formed batch"),
        )
    }
}

/// Source over a pre-materialized table, re-sliced into batches.
pub struct TableSource {
    table: Table,
    offset: usize,
    batch_rows: usize,
}

impl TableSource {
    /// Batch `table` into `batch_rows` chunks.
    pub fn new(table: Table, batch_rows: usize) -> Self {
        assert!(batch_rows > 0);
        TableSource { table, offset: 0, batch_rows }
    }
}

impl Source for TableSource {
    fn next_batch(&mut self) -> Option<Table> {
        if self.offset >= self.table.num_rows() {
            return None;
        }
        let n = self.batch_rows.min(self.table.num_rows() - self.offset);
        let t = self.table.slice(self.offset, n);
        self.offset += n;
        Some(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_batches_cover_total() {
        let mut s = GeneratorSource::new(1, 1050, 100, 0.9);
        let mut total = 0;
        let mut batches = 0;
        while let Some(b) = s.next_batch() {
            total += b.num_rows();
            batches += 1;
        }
        assert_eq!(total, 1050);
        assert_eq!(batches, 11); // 10 full + 1 tail of 50
    }

    #[test]
    fn table_source_slices() {
        let t = crate::datagen::uniform_table(2, 250, 0.9);
        let mut s = TableSource::new(t.clone(), 100);
        let sizes: Vec<usize> = std::iter::from_fn(|| s.next_batch().map(|b| b.num_rows()))
            .collect();
        assert_eq!(sizes, vec![100, 100, 50]);
    }
}

//! Streaming ingestion orchestrator — the data-pipeline face of the
//! coordinator: chunked sources feed **bounded queues** (credit-style
//! backpressure: producers block when consumers lag), sharded across a
//! pool of stage workers, with per-stage throughput accounting.
//!
//! This is the paper's §IV-D-2 "application-level parallelism" story
//! turned into a runnable subsystem: each micro-batch is a small DDF, the
//! stages are DDF operators, and the shard router reuses the same key
//! hashing as the distributed operators, so batches arrive key-sharded
//! exactly like a BSP shuffle would deliver them.

mod pipeline;
mod queue;
mod source;

pub use pipeline::{ShardedStage, StreamPipeline, StreamReport};
pub use queue::BoundedQueue;
pub use source::{GeneratorSource, Source, TableSource};

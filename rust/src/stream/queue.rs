//! Bounded MPMC queue with blocking push (backpressure) and pop.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

struct Inner<T> {
    buf: VecDeque<T>,
    closed: bool,
    // high-water mark: diagnostics for the backpressure report
    max_depth: usize,
    // count of pushes that had to wait (backpressure events)
    stalls: u64,
}

/// Blocking bounded queue. `push` waits while full (backpressure), `pop`
/// waits while empty, `close` wakes all poppers with `None` once drained.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

impl<T> BoundedQueue<T> {
    /// Queue holding at most `capacity` items.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        BoundedQueue {
            inner: Mutex::new(Inner {
                buf: VecDeque::with_capacity(capacity),
                closed: false,
                max_depth: 0,
                stalls: 0,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    /// Blocking push; returns false if the queue was closed.
    pub fn push(&self, item: T) -> bool {
        let mut g = self.inner.lock().expect("queue poisoned");
        if g.buf.len() >= self.capacity {
            g.stalls += 1;
        }
        while g.buf.len() >= self.capacity && !g.closed {
            g = self.not_full.wait(g).expect("queue poisoned");
        }
        if g.closed {
            return false;
        }
        g.buf.push_back(item);
        let depth = g.buf.len();
        if depth > g.max_depth {
            g.max_depth = depth;
        }
        drop(g);
        self.not_empty.notify_one();
        true
    }

    /// Blocking pop; `None` once the queue is closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().expect("queue poisoned");
        loop {
            if let Some(item) = g.buf.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).expect("queue poisoned");
        }
    }

    /// Close the queue: producers fail fast, consumers drain then stop.
    pub fn close(&self) {
        let mut g = self.inner.lock().expect("queue poisoned");
        g.closed = true;
        drop(g);
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    /// Highest observed depth (≤ capacity).
    pub fn max_depth(&self) -> usize {
        self.inner.lock().expect("queue poisoned").max_depth
    }

    /// Number of pushes that blocked on a full queue.
    pub fn stalls(&self) -> u64 {
        self.inner.lock().expect("queue poisoned").stalls
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_roundtrip() {
        let q = BoundedQueue::new(4);
        assert!(q.push(1));
        assert!(q.push(2));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        q.close();
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn backpressure_blocks_and_counts() {
        let q = Arc::new(BoundedQueue::new(2));
        q.push(1);
        q.push(2);
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.push(3)); // blocks
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(!h.is_finished(), "push should be blocked on full queue");
        assert_eq!(q.pop(), Some(1));
        h.join().unwrap();
        assert!(q.stalls() >= 1);
        assert_eq!(q.max_depth(), 2);
    }

    #[test]
    fn close_drains_then_stops() {
        let q = Arc::new(BoundedQueue::new(4));
        q.push(1);
        q.close();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), None);
        assert!(!q.push(9), "push after close must fail");
    }

    #[test]
    fn mpmc_conservation() {
        let q = Arc::new(BoundedQueue::new(8));
        let total = 1000;
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..total / 4 {
                        q.push(p * 1000 + i);
                    }
                })
            })
            .collect();
        let consumed = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = q.clone();
                let c = consumed.clone();
                std::thread::spawn(move || {
                    while q.pop().is_some() {
                        c.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                    }
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        for c in consumers {
            c.join().unwrap();
        }
        assert_eq!(consumed.load(std::sync::atomic::Ordering::SeqCst), total);
    }
}

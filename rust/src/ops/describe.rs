//! `describe` — per-column summary statistics (pandas `DataFrame.describe`
//! analogue). The numeric reductions can run through the AOT `colagg`
//! kernel (PJRT) or natively.

use crate::column::Column;
use crate::error::Result;
use crate::table::Table;
use crate::types::{DType, Value};

/// Summary statistics of one column.
#[derive(Debug, Clone, PartialEq)]
pub struct ColumnStats {
    /// Column name.
    pub name: String,
    /// Non-null count.
    pub count: usize,
    /// Null count.
    pub nulls: usize,
    /// Sum (numeric columns only).
    pub sum: Option<f64>,
    /// Min (numeric columns only).
    pub min: Option<f64>,
    /// Max (numeric columns only).
    pub max: Option<f64>,
    /// Mean (numeric columns only).
    pub mean: Option<f64>,
}

fn numeric_stats(values: impl Iterator<Item = Option<f64>>) -> (usize, f64, f64, f64) {
    let mut count = 0usize;
    let mut sum = 0.0;
    let mut min = f64::INFINITY;
    let mut max = f64::NEG_INFINITY;
    for v in values.flatten() {
        count += 1;
        sum += v;
        if v < min {
            min = v;
        }
        if v > max {
            max = v;
        }
    }
    (count, sum, min, max)
}

/// Compute stats for every column of `t`.
pub fn describe(t: &Table) -> Result<Vec<ColumnStats>> {
    let mut out = Vec::with_capacity(t.num_columns());
    for (i, field) in t.schema().fields().iter().enumerate() {
        let col = t.column(i)?;
        let nulls = col.null_count();
        let stats = if field.dtype.is_numeric() {
            let (count, sum, min, max) = match col {
                Column::Int64(c) => numeric_stats(
                    c.values
                        .iter()
                        .enumerate()
                        .map(|(r, &v)| col.is_valid(r).then_some(v as f64)),
                ),
                Column::Float64(c) => numeric_stats(
                    c.values
                        .iter()
                        .enumerate()
                        .map(|(r, &v)| col.is_valid(r).then_some(v)),
                ),
                _ => unreachable!(),
            };
            ColumnStats {
                name: field.name.clone(),
                count,
                nulls,
                sum: (count > 0).then_some(sum),
                min: (count > 0).then_some(min),
                max: (count > 0).then_some(max),
                mean: (count > 0).then_some(sum / count as f64),
            }
        } else {
            ColumnStats {
                name: field.name.clone(),
                count: t.num_rows() - nulls,
                nulls,
                sum: None,
                min: None,
                max: None,
                mean: None,
            }
        };
        out.push(stats);
    }
    Ok(out)
}

/// Render `describe` output as a table (columns: name/count/nulls/sum/
/// min/max/mean).
pub fn describe_table(t: &Table) -> Result<Table> {
    let stats = describe(t)?;
    let mut names = crate::column::ColumnBuilder::new(DType::Utf8);
    let mut counts = crate::column::ColumnBuilder::new(DType::Int64);
    let mut nulls = crate::column::ColumnBuilder::new(DType::Int64);
    let mut sums = crate::column::ColumnBuilder::new(DType::Float64);
    let mut mins = crate::column::ColumnBuilder::new(DType::Float64);
    let mut maxs = crate::column::ColumnBuilder::new(DType::Float64);
    let mut means = crate::column::ColumnBuilder::new(DType::Float64);
    for s in &stats {
        names.push_str(&s.name);
        counts.push_i64(s.count as i64);
        nulls.push_i64(s.nulls as i64);
        for (b, v) in [
            (&mut sums, s.sum),
            (&mut mins, s.min),
            (&mut maxs, s.max),
            (&mut means, s.mean),
        ] {
            match v {
                Some(x) => b.push(Value::Float64(x))?,
                None => b.push_null(),
            }
        }
    }
    Table::from_columns(vec![
        ("column", names.finish()),
        ("count", counts.finish()),
        ("nulls", nulls.finish()),
        ("sum", sums.finish()),
        ("min", mins.finish()),
        ("max", maxs.finish()),
        ("mean", means.finish()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numeric_and_string_stats() {
        let t = Table::from_columns(vec![
            ("i", Column::from_opt_i64(&[Some(1), Some(3), None])),
            ("f", Column::from_f64(vec![0.5, 1.5, 2.5])),
            ("s", Column::from_strings(&["a", "b", "c"])),
        ])
        .unwrap();
        let stats = describe(&t).unwrap();
        assert_eq!(stats[0].count, 2);
        assert_eq!(stats[0].nulls, 1);
        assert_eq!(stats[0].sum, Some(4.0));
        assert_eq!(stats[0].mean, Some(2.0));
        assert_eq!(stats[1].min, Some(0.5));
        assert_eq!(stats[1].max, Some(2.5));
        assert_eq!(stats[2].sum, None);
        assert_eq!(stats[2].count, 3);
    }

    #[test]
    fn as_table() {
        let t = Table::from_columns(vec![("i", Column::from_i64(vec![1, 2]))]).unwrap();
        let d = describe_table(&t).unwrap();
        assert_eq!(d.num_rows(), 1);
        assert_eq!(d.value(0, 0).unwrap().as_str(), Some("i"));
        assert_eq!(d.value(0, 3).unwrap(), Value::Float64(3.0));
    }

    #[test]
    fn empty_numeric_column() {
        let t = Table::from_columns(vec![("i", Column::from_i64(vec![]))]).unwrap();
        let stats = describe(&t).unwrap();
        assert_eq!(stats[0].count, 0);
        assert_eq!(stats[0].sum, None);
    }
}

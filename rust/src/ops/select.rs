//! Row/column selection utilities completing the DDF API surface:
//! head/tail/limit, column rename/drop — the cheap relational-algebra
//! scaffolding every dataframe user expects.

use crate::error::{Error, Result};
use crate::executor::MorselPool;
use crate::table::Table;
use crate::types::{Field, Schema};

/// First `n` rows (all rows when `n ≥ len`).
pub fn head(t: &Table, n: usize) -> Table {
    t.slice(0, n.min(t.num_rows()))
}

/// Last `n` rows.
pub fn tail(t: &Table, n: usize) -> Table {
    let n = n.min(t.num_rows());
    t.slice(t.num_rows() - n, n)
}

/// Alias of [`head`] (SQL LIMIT).
pub fn limit(t: &Table, n: usize) -> Table {
    head(t, n)
}

/// Rename a column (by name) returning a new table.
pub fn rename(t: &Table, from: &str, to: &str) -> Result<Table> {
    let idx = t.schema().index_of(from)?;
    if t.schema().index_of(to).is_ok() {
        return Err(Error::schema(format!("column '{to}' already exists")));
    }
    let fields: Vec<Field> = t
        .schema()
        .fields()
        .iter()
        .enumerate()
        .map(|(i, f)| {
            if i == idx {
                Field::new(to, f.dtype)
            } else {
                f.clone()
            }
        })
        .collect();
    Table::new(Schema::new(fields), t.columns().to_vec())
}

/// Drop columns by name, returning the projection onto the rest.
pub fn drop_columns(t: &Table, names: &[&str]) -> Result<Table> {
    let mut drop_idx = Vec::with_capacity(names.len());
    for n in names {
        drop_idx.push(t.schema().index_of(n)?);
    }
    let keep: Vec<usize> = (0..t.num_columns())
        .filter(|i| !drop_idx.contains(i))
        .collect();
    if keep.is_empty() {
        return Err(Error::schema("cannot drop every column"));
    }
    t.project(&keep)
}

/// Select columns by name, in the given order.
pub fn select(t: &Table, names: &[&str]) -> Result<Table> {
    select_with_pool(t, names, &MorselPool::disabled())
}

/// [`select`] on a morsel pool ([`project_with_pool`] by resolved index).
pub fn select_with_pool(t: &Table, names: &[&str], pool: &MorselPool) -> Result<Table> {
    let mut idx = Vec::with_capacity(names.len());
    for n in names {
        idx.push(t.schema().index_of(n)?);
    }
    project_with_pool(t, &idx, pool)
}

/// [`Table::project`] on a morsel pool: each selected column clones as
/// its own parallel task (the clone *is* the unit of work — column order,
/// and therefore the output table, never depends on scheduling).
pub fn project_with_pool(t: &Table, idx: &[usize], pool: &MorselPool) -> Result<Table> {
    if !pool.is_parallel() || idx.len() <= 1 {
        return t.project(idx);
    }
    let mut fields = Vec::with_capacity(idx.len());
    for &c in idx {
        fields.push(t.schema().field(c)?.clone());
    }
    let columns = pool.run(idx.len(), |i| t.columns()[idx[i]].clone());
    Table::new(Schema::new(fields), columns)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::types::Value;

    fn t() -> Table {
        Table::from_columns(vec![
            ("k", Column::from_i64(vec![1, 2, 3, 4, 5])),
            ("v", Column::from_i64(vec![10, 20, 30, 40, 50])),
        ])
        .unwrap()
    }

    #[test]
    fn head_tail_limit() {
        assert_eq!(head(&t(), 2).column(0).unwrap().i64_values().unwrap(), &[1, 2]);
        assert_eq!(tail(&t(), 2).column(0).unwrap().i64_values().unwrap(), &[4, 5]);
        assert_eq!(limit(&t(), 100).num_rows(), 5);
        assert_eq!(head(&t(), 0).num_rows(), 0);
    }

    #[test]
    fn rename_checks_collisions() {
        let r = rename(&t(), "v", "value").unwrap();
        assert_eq!(r.schema().field(1).unwrap().name, "value");
        assert_eq!(r.value(0, 1).unwrap(), Value::Int64(10));
        assert!(rename(&t(), "v", "k").is_err());
        assert!(rename(&t(), "zzz", "x").is_err());
    }

    #[test]
    fn drop_and_select() {
        let d = drop_columns(&t(), &["k"]).unwrap();
        assert_eq!(d.num_columns(), 1);
        assert_eq!(d.schema().field(0).unwrap().name, "v");
        assert!(drop_columns(&t(), &["k", "v"]).is_err());
        let s = select(&t(), &["v", "k"]).unwrap();
        assert_eq!(s.schema().field(0).unwrap().name, "v");
        assert_eq!(s.schema().field(1).unwrap().name, "k");
    }
}

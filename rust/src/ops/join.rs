//! Local join kernels: hash join (default) and sort-merge join.
//!
//! These are the *core local operator* of the paper's Fig 2 distributed
//! join: in the distributed setting both inputs are hash-shuffled on their
//! key columns first, then each worker runs this local join on its
//! co-partitioned pair.

use super::kernels::{
    approx_row_bytes, row_hashes_range, rows_cmp, rows_equal, utf8_dict_encode, utf8_dict_lookup,
    KeyHasher, NativeHasher,
};
use crate::column::Column;
use crate::error::{Error, Result};
use crate::executor::MorselPool;
use crate::table::Table;
use crate::util::hash::{fast_map_with_capacity, partition_of, FastMap};
use std::cmp::Ordering;

/// Join type (SQL semantics; nulls never match nulls).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JoinType {
    /// Rows with matches on both sides.
    Inner,
    /// All left rows; unmatched right side is null-filled.
    Left,
    /// All right rows; unmatched left side is null-filled.
    Right,
    /// All rows from both sides.
    FullOuter,
}

/// Join algorithm selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JoinAlgo {
    /// Build a hash table on the smaller side, probe with the larger.
    Hash,
    /// Sort both sides on keys, merge. (Cylon exposes both.)
    SortMerge,
}

/// Options for [`join`].
#[derive(Debug, Clone)]
pub struct JoinOptions {
    /// Key column indices on the left table.
    pub left_on: Vec<usize>,
    /// Key column indices on the right table.
    pub right_on: Vec<usize>,
    /// Join type.
    pub join_type: JoinType,
    /// Algorithm.
    pub algo: JoinAlgo,
}

impl JoinOptions {
    /// Inner hash join on single key columns.
    pub fn inner(left_on: usize, right_on: usize) -> Self {
        JoinOptions {
            left_on: vec![left_on],
            right_on: vec![right_on],
            join_type: JoinType::Inner,
            algo: JoinAlgo::Hash,
        }
    }

    /// Builder-style join type override.
    pub fn with_type(mut self, jt: JoinType) -> Self {
        self.join_type = jt;
        self
    }

    /// Builder-style algorithm override.
    pub fn with_algo(mut self, a: JoinAlgo) -> Self {
        self.algo = a;
        self
    }

    fn validate(&self, left: &Table, right: &Table) -> Result<()> {
        if self.left_on.is_empty() || self.left_on.len() != self.right_on.len() {
            return Err(Error::invalid(
                "join requires equal, non-empty key column lists",
            ));
        }
        for &c in &self.left_on {
            left.column(c)?;
        }
        for &c in &self.right_on {
            right.column(c)?;
        }
        for (&lc, &rc) in self.left_on.iter().zip(&self.right_on) {
            let lt = left.schema().dtype(lc)?;
            let rt = right.schema().dtype(rc)?;
            if lt != rt {
                return Err(Error::Type(format!(
                    "join key dtype mismatch: {lt} vs {rt}"
                )));
            }
        }
        Ok(())
    }
}

/// Join two tables. Output schema is `left ++ right` with right-side name
/// collisions prefixed `rhs_`.
pub fn join(left: &Table, right: &Table, opts: &JoinOptions) -> Result<Table> {
    join_with_hasher(left, right, opts, &NativeHasher)
}

/// [`join`] with an explicit key-hasher (PJRT or native).
pub fn join_with_hasher(
    left: &Table,
    right: &Table,
    opts: &JoinOptions,
    hasher: &dyn KeyHasher,
) -> Result<Table> {
    join_with_pool(left, right, opts, hasher, &MorselPool::disabled())
}

/// [`join_with_hasher`] on a morsel pool. The hash join partitions the
/// build side by key hash, builds one hash table per partition in
/// parallel (stable ascending scatter keeps every chain's LIFO order
/// identical to the serial build), then probes in parallel morsels whose
/// match lists concatenate in morsel (= probe row) order — so the output
/// is byte-identical to the serial join (DESIGN.md §11). Sort-merge joins
/// stay serial.
pub fn join_with_pool(
    left: &Table,
    right: &Table,
    opts: &JoinOptions,
    hasher: &dyn KeyHasher,
    pool: &MorselPool,
) -> Result<Table> {
    opts.validate(left, right)?;
    let (lidx, ridx) = match opts.algo {
        JoinAlgo::Hash => hash_join_indices(left, right, opts, hasher, pool)?,
        JoinAlgo::SortMerge => sort_merge_indices(left, right, opts)?,
    };
    materialize(left, right, &lidx, &ridx, pool)
}

/// A row is a valid join key only if *no* key column is null (SQL).
fn row_key_valid(t: &Table, row: usize, cols: &[usize]) -> bool {
    cols.iter().all(|&c| t.columns()[c].is_valid(row))
}

/// How the per-row i64 key representation relates to key equality.
///
/// `Exact`/`Dict`: i64 equality *is* key equality (single non-null int64
/// keys, or single string keys dictionary-encoded against the build
/// side). `Hashed`: the i64 is a row hash — collisions are resolved with
/// [`rows_equal`] and null keys are filtered via [`row_key_valid`].
enum KeyRep<'a> {
    Exact { b: &'a [i64], p: &'a [i64] },
    Dict { b: Vec<i64>, p: Vec<i64> },
    Hashed { b: Vec<i64>, p: Vec<i64> },
}

fn hash_join_indices(
    left: &Table,
    right: &Table,
    opts: &JoinOptions,
    hasher: &dyn KeyHasher,
    pool: &MorselPool,
) -> Result<(Vec<u32>, Vec<u32>)> {
    // Build on the smaller side; probe from the larger. For Right/Left we
    // keep orientation fixed (build=right for Left, build=left for Right)
    // so the outer side streams.
    let build_left = match opts.join_type {
        JoinType::Inner | JoinType::FullOuter => left.num_rows() <= right.num_rows(),
        JoinType::Left => false,
        JoinType::Right => true,
    };
    let (bt, bcols, pt, pcols) = if build_left {
        (left, &opts.left_on, right, &opts.right_on)
    } else {
        (right, &opts.right_on, left, &opts.left_on)
    };
    let emit_unmatched_probe = matches!(
        (opts.join_type, build_left),
        (JoinType::Left, false) | (JoinType::Right, true) | (JoinType::FullOuter, _)
    );
    let emit_unmatched_build = matches!(opts.join_type, JoinType::FullOuter);

    // Key representation. Single non-null int64 keys join on the value
    // itself — no row-hash pass, no generic equality (§Perf L3 iter 2).
    // Single string keys dictionary-encode the build side and translate
    // probe strings to codes (negative = null or absent from the build,
    // i.e. unmatchable). Everything else falls back to row hashes.
    let rep: KeyRep = match (bcols.as_slice(), pcols.as_slice()) {
        ([bc], [pc]) => match (&bt.columns()[*bc], &pt.columns()[*pc]) {
            (Column::Int64(b), Column::Int64(p))
                if b.validity.is_none() && p.validity.is_none() =>
            {
                KeyRep::Exact { b: &b.values, p: &p.values }
            }
            (Column::Utf8(b), Column::Utf8(p)) => {
                let (dict, bcodes) = utf8_dict_encode(b);
                let pcodes = utf8_dict_lookup(p, &dict);
                KeyRep::Dict { b: bcodes, p: pcodes }
            }
            _ => hashed_rep(bt, bcols, pt, pcols, hasher, pool)?,
        },
        _ => hashed_rep(bt, bcols, pt, pcols, hasher, pool)?,
    };
    let (bkeys, pkeys): (&[i64], &[i64]) = match &rep {
        KeyRep::Exact { b, p } => (b, p),
        KeyRep::Dict { b, p } => (b, p),
        KeyRep::Hashed { b, p } => (b, p),
    };
    let exact = !matches!(rep, KeyRep::Hashed { .. });
    // Whether a build row may enter the table / a probe row may look up.
    let b_usable = |row: usize| match &rep {
        KeyRep::Exact { .. } => true,
        KeyRep::Dict { b, .. } => b[row] >= 0,
        KeyRep::Hashed { .. } => row_key_valid(bt, row, bcols),
    };
    let p_usable = |row: usize| match &rep {
        KeyRep::Exact { .. } => true,
        KeyRep::Dict { p, .. } => p[row] >= 0,
        KeyRep::Hashed { .. } => row_key_valid(pt, row, pcols),
    };

    // Partitioned build. Usable build rows scatter stably (ascending row
    // order) into P key-hash partitions; each partition builds its own
    // head map + LIFO chain over local positions. All rows of one key
    // land in one partition with their ascending order intact, so every
    // chain links exactly the rows the serial single-table build links,
    // in the same (descending-row) order.
    let parts = if pool.is_parallel() { pool.threads() } else { 1 };
    let bn = bt.num_rows();
    let mut counts = vec![0u32; parts];
    let pid_of = |key: i64| if parts == 1 { 0 } else { partition_of(key, parts) };
    for row in 0..bn {
        if b_usable(row) {
            counts[pid_of(bkeys[row])] += 1;
        }
    }
    let mut offsets = vec![0usize; parts + 1];
    for p in 0..parts {
        offsets[p + 1] = offsets[p] + counts[p] as usize;
    }
    let mut order = vec![0u32; offsets[parts]];
    let mut cursor = offsets[..parts].to_vec();
    for row in 0..bn {
        if b_usable(row) {
            let p = pid_of(bkeys[row]);
            order[cursor[p]] = row as u32;
            cursor[p] += 1;
        }
    }
    // head: key -> local position of chain head; next: local position ->
    // previous local position with the same key (u32::MAX terminates).
    let tables: Vec<(FastMap<i64, u32>, Vec<u32>)> = pool.run(parts, |p| {
        let rows = &order[offsets[p]..offsets[p + 1]];
        let mut head: FastMap<i64, u32> = fast_map_with_capacity(rows.len());
        let mut next: Vec<u32> = vec![u32::MAX; rows.len()];
        for (local, &row) in rows.iter().enumerate() {
            let e = head.entry(bkeys[row as usize]).or_insert(u32::MAX);
            next[local] = *e;
            *e = local as u32;
        }
        (head, next)
    });

    // Parallel probe: each morsel emits its (build, probe) match pairs in
    // probe-row order; chunks concatenate in morsel order, reproducing
    // the serial probe loop's emission order exactly.
    let ranges = pool.ranges(pt.num_rows(), approx_row_bytes(pt));
    let chunks = pool.run(ranges.len(), |m| {
        let (start, len) = ranges[m];
        let mut bi: Vec<u32> = Vec::new();
        let mut pi: Vec<u32> = Vec::new();
        for p in start..start + len {
            let mut matched = false;
            if p_usable(p) {
                let k = pkeys[p];
                let pid = pid_of(k);
                let (head, next) = &tables[pid];
                let mut local = head.get(&k).copied().unwrap_or(u32::MAX);
                while local != u32::MAX {
                    let b = order[offsets[pid] + local as usize];
                    if exact || rows_equal(bt, b as usize, bcols, pt, p, pcols) {
                        bi.push(b);
                        pi.push(p as u32);
                        matched = true;
                    }
                    local = next[local as usize];
                }
            }
            if !matched && emit_unmatched_probe {
                bi.push(u32::MAX);
                pi.push(p as u32);
            }
        }
        (bi, pi)
    });

    let mut build_idx: Vec<u32> = Vec::new();
    let mut probe_idx: Vec<u32> = Vec::new();
    let mut build_matched = vec![false; bn];
    for (bi, pi) in chunks {
        for &b in &bi {
            if b != u32::MAX {
                build_matched[b as usize] = true;
            }
        }
        build_idx.extend(bi);
        probe_idx.extend(pi);
    }
    if emit_unmatched_build {
        // null-keyed build rows still appear in a full outer join
        for (b, m) in build_matched.iter().enumerate() {
            if !m {
                build_idx.push(b as u32);
                probe_idx.push(u32::MAX);
            }
        }
    }
    // Also: Left join with null-keyed *left* rows must emit them; covered
    // because probe side is left there and null keys fall into !matched.
    if build_left {
        Ok((build_idx, probe_idx))
    } else {
        Ok((probe_idx, build_idx))
    }
}

/// Row-hash [`KeyRep`] for the generic path, hashed in parallel morsels.
fn hashed_rep<'a>(
    bt: &Table,
    bcols: &[usize],
    pt: &Table,
    pcols: &[usize],
    hasher: &dyn KeyHasher,
    pool: &MorselPool,
) -> Result<KeyRep<'a>> {
    let mut sides: Vec<Vec<i64>> = Vec::with_capacity(2);
    for (t, cols) in [(bt, bcols), (pt, pcols)] {
        let ranges = pool.ranges(t.num_rows(), approx_row_bytes(t));
        let chunks = pool.run(ranges.len(), |m| {
            let (start, len) = ranges[m];
            row_hashes_range(t, cols, hasher, start, len)
        });
        let mut h = Vec::with_capacity(t.num_rows());
        for ch in chunks {
            h.extend(ch?);
        }
        sides.push(h);
    }
    let p = sides.pop().expect("two sides");
    let b = sides.pop().expect("two sides");
    Ok(KeyRep::Hashed { b, p })
}

fn sort_merge_indices(
    left: &Table,
    right: &Table,
    opts: &JoinOptions,
) -> Result<(Vec<u32>, Vec<u32>)> {
    let mut lorder: Vec<u32> = (0..left.num_rows() as u32).collect();
    let mut rorder: Vec<u32> = (0..right.num_rows() as u32).collect();
    lorder.sort_unstable_by(|&a, &b| {
        rows_cmp(left, a as usize, &opts.left_on, left, b as usize, &opts.left_on)
    });
    rorder.sort_unstable_by(|&a, &b| {
        rows_cmp(right, a as usize, &opts.right_on, right, b as usize, &opts.right_on)
    });

    let mut lidx = Vec::new();
    let mut ridx = Vec::new();
    let (mut i, mut j) = (0usize, 0usize);
    let mut lmatched = vec![false; left.num_rows()];
    let mut rmatched = vec![false; right.num_rows()];
    while i < lorder.len() && j < rorder.len() {
        let li = lorder[i] as usize;
        let rj = rorder[j] as usize;
        let lvalid = row_key_valid(left, li, &opts.left_on);
        let rvalid = row_key_valid(right, rj, &opts.right_on);
        // nulls sort first: skip them (they cannot match)
        if !lvalid {
            i += 1;
            continue;
        }
        if !rvalid {
            j += 1;
            continue;
        }
        match rows_cmp(left, li, &opts.left_on, right, rj, &opts.right_on) {
            Ordering::Less => i += 1,
            Ordering::Greater => j += 1,
            Ordering::Equal => {
                // find both equal runs, emit the cross product
                let mut ie = i;
                while ie < lorder.len()
                    && rows_cmp(left, lorder[ie] as usize, &opts.left_on, left, li, &opts.left_on)
                        == Ordering::Equal
                {
                    ie += 1;
                }
                let mut je = j;
                while je < rorder.len()
                    && rows_cmp(
                        right,
                        rorder[je] as usize,
                        &opts.right_on,
                        right,
                        rj,
                        &opts.right_on,
                    ) == Ordering::Equal
                {
                    je += 1;
                }
                for a in i..ie {
                    for b in j..je {
                        lidx.push(lorder[a]);
                        ridx.push(rorder[b]);
                        lmatched[lorder[a] as usize] = true;
                        rmatched[rorder[b] as usize] = true;
                    }
                }
                i = ie;
                j = je;
            }
        }
    }
    let emit_left = matches!(opts.join_type, JoinType::Left | JoinType::FullOuter);
    let emit_right = matches!(opts.join_type, JoinType::Right | JoinType::FullOuter);
    if emit_left {
        for (r, m) in lmatched.iter().enumerate() {
            if !m {
                lidx.push(r as u32);
                ridx.push(u32::MAX);
            }
        }
    }
    if emit_right {
        for (r, m) in rmatched.iter().enumerate() {
            if !m {
                lidx.push(u32::MAX);
                ridx.push(r as u32);
            }
        }
    }
    Ok((lidx, ridx))
}

fn materialize(
    left: &Table,
    right: &Table,
    lidx: &[u32],
    ridx: &[u32],
    pool: &MorselPool,
) -> Result<Table> {
    let schema = left.schema().merge_for_join(right.schema());
    // Output columns are independent gathers — one parallel task each.
    let nl = left.num_columns();
    let columns: Vec<Column> = pool.run(nl + right.num_columns(), |ci| {
        if ci < nl {
            left.columns()[ci].gather_opt(lidx)
        } else {
            right.columns()[ci - nl].gather_opt(ridx)
        }
    });
    Table::new(schema, columns)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Value;

    fn left() -> Table {
        Table::from_columns(vec![
            ("k", Column::from_i64(vec![1, 2, 2, 3])),
            ("lv", Column::from_i64(vec![10, 20, 21, 30])),
        ])
        .unwrap()
    }

    fn right() -> Table {
        Table::from_columns(vec![
            ("k", Column::from_i64(vec![2, 3, 3, 4])),
            ("rv", Column::from_i64(vec![200, 300, 301, 400])),
        ])
        .unwrap()
    }

    fn rows(t: &Table) -> Vec<Vec<Value>> {
        let mut out: Vec<Vec<Value>> = (0..t.num_rows())
            .map(|r| (0..t.num_columns()).map(|c| t.value(r, c).unwrap()).collect())
            .collect();
        out.sort_by(|a, b| format!("{a:?}").cmp(&format!("{b:?}")));
        out
    }

    #[test]
    fn inner_hash_vs_sort_merge_agree() {
        let h = join(&left(), &right(), &JoinOptions::inner(0, 0)).unwrap();
        let s = join(
            &left(),
            &right(),
            &JoinOptions::inner(0, 0).with_algo(JoinAlgo::SortMerge),
        )
        .unwrap();
        // inner: k=2 matches 2 left x 1 right = 2 rows, k=3 matches 1 x 2 = 2 rows
        assert_eq!(h.num_rows(), 4);
        assert_eq!(rows(&h), rows(&s));
        assert_eq!(h.schema().field(2).unwrap().name, "rhs_k");
    }

    #[test]
    fn left_join_fills_nulls() {
        let t = join(
            &left(),
            &right(),
            &JoinOptions::inner(0, 0).with_type(JoinType::Left),
        )
        .unwrap();
        // 4 matches + unmatched k=1
        assert_eq!(t.num_rows(), 5);
        let unmatched: Vec<usize> = (0..t.num_rows())
            .filter(|&r| t.value(r, 2).unwrap().is_null())
            .collect();
        assert_eq!(unmatched.len(), 1);
        assert_eq!(t.value(unmatched[0], 0).unwrap(), Value::Int64(1));
    }

    #[test]
    fn right_and_outer() {
        let r = join(
            &left(),
            &right(),
            &JoinOptions::inner(0, 0).with_type(JoinType::Right),
        )
        .unwrap();
        assert_eq!(r.num_rows(), 5); // 4 matches + unmatched k=4
        let o = join(
            &left(),
            &right(),
            &JoinOptions::inner(0, 0).with_type(JoinType::FullOuter),
        )
        .unwrap();
        assert_eq!(o.num_rows(), 6); // + unmatched k=1 and k=4
        let sm = join(
            &left(),
            &right(),
            &JoinOptions::inner(0, 0)
                .with_type(JoinType::FullOuter)
                .with_algo(JoinAlgo::SortMerge),
        )
        .unwrap();
        assert_eq!(rows(&o), rows(&sm));
    }

    #[test]
    fn null_keys_do_not_match() {
        let l = Table::from_columns(vec![("k", Column::from_opt_i64(&[None, Some(1)]))]).unwrap();
        let r = Table::from_columns(vec![("k", Column::from_opt_i64(&[None, Some(1)]))]).unwrap();
        let t = join(&l, &r, &JoinOptions::inner(0, 0)).unwrap();
        assert_eq!(t.num_rows(), 1); // only (1,1)
        let lo = join(&l, &r, &JoinOptions::inner(0, 0).with_type(JoinType::Left)).unwrap();
        assert_eq!(lo.num_rows(), 2); // null left row survives
    }

    #[test]
    fn multi_key_join() {
        let l = Table::from_columns(vec![
            ("a", Column::from_i64(vec![1, 1, 2])),
            ("b", Column::from_strings(&["x", "y", "x"])),
        ])
        .unwrap();
        let r = Table::from_columns(vec![
            ("a", Column::from_i64(vec![1, 2])),
            ("b", Column::from_strings(&["y", "x"])),
        ])
        .unwrap();
        let opts = JoinOptions {
            left_on: vec![0, 1],
            right_on: vec![0, 1],
            join_type: JoinType::Inner,
            algo: JoinAlgo::Hash,
        };
        let t = join(&l, &r, &opts).unwrap();
        assert_eq!(t.num_rows(), 2); // (1,y) and (2,x)
    }

    #[test]
    fn key_dtype_mismatch_errors() {
        let l = Table::from_columns(vec![("k", Column::from_i64(vec![1]))]).unwrap();
        let r = Table::from_columns(vec![("k", Column::from_f64(vec![1.0]))]).unwrap();
        assert!(join(&l, &r, &JoinOptions::inner(0, 0)).is_err());
    }

    #[test]
    fn empty_inputs() {
        let e = Table::empty(left().schema().clone());
        let t = join(&e, &right(), &JoinOptions::inner(0, 0)).unwrap();
        assert_eq!(t.num_rows(), 0);
        let t2 = join(
            &e,
            &right(),
            &JoinOptions::inner(0, 0).with_type(JoinType::Right),
        )
        .unwrap();
        assert_eq!(t2.num_rows(), 4);
    }
}

//! Local (single-partition) dataframe operators — the paper's *core local
//! operators* (§III-B-1).
//!
//! Every distributed operator in [`crate::dist`] is composed of these local
//! kernels plus communication routines ([`crate::comm`]), mirroring the
//! paper's sub-operator decomposition: *core local op* + *auxiliary local
//! ops* + *communication ops*.

pub mod arith;
pub mod describe;
pub mod distinct;
pub mod filter;
pub mod groupby;
pub mod join;
pub mod kernels;
pub mod merge;
pub mod partition;
pub mod sample;
pub mod scalar;
pub mod select;
pub mod setops;
pub mod sort;

pub use arith::{binary_op, compare, with_binary, BinOp, CmpOp};
pub use describe::{describe, describe_table, ColumnStats};
pub use distinct::distinct;
pub use filter::{filter, filter_by_column};
pub use groupby::{groupby, groupby_with_hasher, AggFun, AggSpec};
pub use join::{join, join_with_hasher, JoinAlgo, JoinOptions, JoinType};
pub use kernels::{KeyHasher, NativeHasher};
pub use merge::merge_sorted;
pub use partition::{
    partition_by_hash, partition_by_range, partition_by_range_directed,
    partition_by_range_directed_spread,
};
pub use sample::{sample_rows, splitters_from_sample};
pub use scalar::{add_scalar, mul_scalar};
pub use select::{drop_columns, head, limit, rename, select, tail};
pub use setops::{difference, intersect, union_all, union_distinct};
pub use sort::{sort, SortKey, SortOptions};

//! Local (single-partition) dataframe operators — the paper's *core local
//! operators* (§III-B-1).
//!
//! Every distributed operator in [`crate::dist`] is composed of these local
//! kernels plus communication routines ([`crate::comm`]), mirroring the
//! paper's sub-operator decomposition: *core local op* + *auxiliary local
//! ops* + *communication ops*.

pub mod arith;
pub mod describe;
pub mod distinct;
pub mod filter;
pub mod groupby;
pub mod join;
pub mod kernels;
pub mod merge;
pub mod partition;
pub mod sample;
pub mod scalar;
pub mod select;
pub mod setops;
pub mod sort;

pub use arith::{binary_op, compare, with_binary, BinOp, CmpOp};
pub use describe::{describe, describe_table, ColumnStats};
pub use distinct::distinct;
pub use filter::{filter, filter_by_column, filter_by_column_with_pool, filter_with_pool};
pub use groupby::{groupby, groupby_with_hasher, groupby_with_pool, AggFun, AggSpec};
pub use join::{join, join_with_hasher, join_with_pool, JoinAlgo, JoinOptions, JoinType};
pub use kernels::{utf8_dict_encode, utf8_dict_lookup, KeyHasher, NativeHasher};
pub use merge::merge_sorted;
pub use partition::{
    partition_by_hash, partition_by_hash_with_pool, partition_by_range,
    partition_by_range_directed, partition_by_range_directed_spread,
};
pub use sample::{sample_rows, splitters_from_sample};
pub use scalar::{add_scalar, mul_scalar};
pub use select::{
    drop_columns, head, limit, project_with_pool, rename, select, select_with_pool, tail,
};
pub use setops::{difference, intersect, union_all, union_distinct};
pub use sort::{
    sort, sort_indices, sort_indices_with_pool, sort_with_pool, SortKey, SortOptions,
};

//! Column-wise arithmetic and comparisons (vectorized, null-propagating) —
//! the element-wise slice of the DDF operator surface.

use crate::buffer::Bitmap;
use crate::column::{BoolColumn, Column, Float64Column, Int64Column};
use crate::error::{Error, Result};
use crate::table::Table;

/// Binary arithmetic operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    /// Wrapping add (ints) / IEEE add (floats).
    Add,
    /// Wrapping sub / IEEE sub.
    Sub,
    /// Wrapping mul / IEEE mul.
    Mul,
    /// Division; int/0 and float/0 produce null.
    Div,
}

/// Comparison operator producing a bool column.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpOp {
    /// Equal.
    Eq,
    /// Not equal.
    Ne,
    /// Less than.
    Lt,
    /// Less or equal.
    Le,
    /// Greater than.
    Gt,
    /// Greater or equal.
    Ge,
}

fn zip_validity(a: &Column, b: &Column) -> Option<Bitmap> {
    match (a.validity(), b.validity()) {
        (None, None) => None,
        (Some(x), None) => Some(x.clone()),
        (None, Some(y)) => Some(y.clone()),
        (Some(x), Some(y)) => Some(x.and(y)),
    }
}

/// `a OP b` element-wise; both columns must share a numeric dtype and
/// length. Nulls propagate; division by zero yields null.
pub fn binary_op(a: &Column, b: &Column, op: BinOp) -> Result<Column> {
    if a.len() != b.len() {
        return Err(Error::invalid(format!(
            "column length mismatch: {} vs {}",
            a.len(),
            b.len()
        )));
    }
    match (a, b) {
        (Column::Int64(x), Column::Int64(y)) => {
            let mut validity = zip_validity(a, b).unwrap_or_else(|| Bitmap::new_valid(a.len()));
            let values: Vec<i64> = x
                .values
                .iter()
                .zip(&y.values)
                .enumerate()
                .map(|(i, (&xa, &xb))| match op {
                    BinOp::Add => xa.wrapping_add(xb),
                    BinOp::Sub => xa.wrapping_sub(xb),
                    BinOp::Mul => xa.wrapping_mul(xb),
                    BinOp::Div => {
                        if xb == 0 {
                            validity.set(i, false);
                            0
                        } else {
                            xa.wrapping_div(xb)
                        }
                    }
                })
                .collect();
            Ok(Column::Int64(Int64Column::new(values, Some(validity))))
        }
        (Column::Float64(x), Column::Float64(y)) => {
            let mut validity = zip_validity(a, b).unwrap_or_else(|| Bitmap::new_valid(a.len()));
            let values: Vec<f64> = x
                .values
                .iter()
                .zip(&y.values)
                .enumerate()
                .map(|(i, (&xa, &xb))| match op {
                    BinOp::Add => xa + xb,
                    BinOp::Sub => xa - xb,
                    BinOp::Mul => xa * xb,
                    BinOp::Div => {
                        if xb == 0.0 {
                            validity.set(i, false);
                            0.0
                        } else {
                            xa / xb
                        }
                    }
                })
                .collect();
            Ok(Column::Float64(Float64Column::new(values, Some(validity))))
        }
        _ => Err(Error::Type(format!(
            "binary op needs matching numeric dtypes, got {} and {}",
            a.dtype(),
            b.dtype()
        ))),
    }
}

/// `a CMP b` element-wise; mismatched/NaN comparisons are false, null
/// inputs yield null.
pub fn compare(a: &Column, b: &Column, op: CmpOp) -> Result<Column> {
    if a.len() != b.len() {
        return Err(Error::invalid("column length mismatch"));
    }
    let validity = zip_validity(a, b);
    let eval = |ord: Option<std::cmp::Ordering>| -> bool {
        use std::cmp::Ordering::*;
        match (op, ord) {
            (CmpOp::Eq, Some(Equal)) => true,
            (CmpOp::Ne, Some(Less | Greater)) => true,
            (CmpOp::Lt, Some(Less)) => true,
            (CmpOp::Le, Some(Less | Equal)) => true,
            (CmpOp::Gt, Some(Greater)) => true,
            (CmpOp::Ge, Some(Greater | Equal)) => true,
            _ => false,
        }
    };
    let values: Vec<bool> = match (a, b) {
        (Column::Int64(x), Column::Int64(y)) => x
            .values
            .iter()
            .zip(&y.values)
            .map(|(xa, xb)| eval(Some(xa.cmp(xb))))
            .collect(),
        (Column::Float64(x), Column::Float64(y)) => x
            .values
            .iter()
            .zip(&y.values)
            .map(|(xa, xb)| eval(xa.partial_cmp(xb)))
            .collect(),
        (Column::Utf8(x), Column::Utf8(y)) => (0..a.len())
            .map(|i| eval(Some(x.get(i).cmp(y.get(i)))))
            .collect(),
        _ => {
            return Err(Error::Type(format!(
                "compare needs matching dtypes, got {} and {}",
                a.dtype(),
                b.dtype()
            )))
        }
    };
    Ok(Column::Bool(BoolColumn::new(values, validity)))
}

/// Table helper: `out_name = t[a] OP t[b]` appended as a new column.
pub fn with_binary(t: &Table, a: usize, b: usize, op: BinOp, out_name: &str) -> Result<Table> {
    let col = binary_op(t.column(a)?, t.column(b)?, op)?;
    t.with_column(out_name, col)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Value;

    #[test]
    fn int_arith_with_div_by_zero() {
        let a = Column::from_i64(vec![6, 7, 8]);
        let b = Column::from_i64(vec![2, 0, 4]);
        let d = binary_op(&a, &b, BinOp::Div).unwrap();
        assert_eq!(d.value(0), Value::Int64(3));
        assert_eq!(d.value(1), Value::Null);
        assert_eq!(d.value(2), Value::Int64(2));
        let m = binary_op(&a, &b, BinOp::Mul).unwrap();
        assert_eq!(m.value(1), Value::Int64(0));
    }

    #[test]
    fn null_propagation() {
        let a = Column::from_opt_i64(&[Some(1), None]);
        let b = Column::from_i64(vec![1, 1]);
        let s = binary_op(&a, &b, BinOp::Add).unwrap();
        assert_eq!(s.value(0), Value::Int64(2));
        assert!(s.value(1).is_null());
    }

    #[test]
    fn float_and_string_compare() {
        let a = Column::from_f64(vec![1.0, f64::NAN]);
        let b = Column::from_f64(vec![1.0, 1.0]);
        let e = compare(&a, &b, CmpOp::Eq).unwrap();
        assert_eq!(e.value(0), Value::Bool(true));
        assert_eq!(e.value(1), Value::Bool(false)); // NaN never equal
        let s1 = Column::from_strings(&["a", "c"]);
        let s2 = Column::from_strings(&["b", "b"]);
        let lt = compare(&s1, &s2, CmpOp::Lt).unwrap();
        assert_eq!(lt.value(0), Value::Bool(true));
        assert_eq!(lt.value(1), Value::Bool(false));
    }

    #[test]
    fn table_with_binary_then_filter() {
        let t = Table::from_columns(vec![
            ("a", Column::from_i64(vec![1, 5, 10])),
            ("b", Column::from_i64(vec![1, 1, 1])),
        ])
        .unwrap();
        let t2 = with_binary(&t, 0, 1, BinOp::Add, "sum").unwrap();
        assert_eq!(t2.num_columns(), 3);
        assert_eq!(t2.value(2, 2).unwrap(), Value::Int64(11));
        let mask = compare(t2.column(2).unwrap(), t2.column(0).unwrap(), CmpOp::Gt).unwrap();
        let t3 = t2.with_column("m", mask).unwrap();
        let f = crate::ops::filter_by_column(&t3, 3).unwrap();
        assert_eq!(f.num_rows(), 3);
    }

    #[test]
    fn type_errors() {
        let a = Column::from_i64(vec![1]);
        let b = Column::from_f64(vec![1.0]);
        assert!(binary_op(&a, &b, BinOp::Add).is_err());
        assert!(compare(&a, &b, CmpOp::Eq).is_err());
        let c = Column::from_i64(vec![1, 2]);
        assert!(binary_op(&a, &c, BinOp::Add).is_err());
    }
}

//! K-way merge of sorted tables — the final local step of distributed sort
//! when workers receive pre-sorted runs, and the repartitioner's combiner.

use super::kernels::rows_cmp;
use super::sort::SortOptions;
use crate::error::Result;
use crate::table::Table;
use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

struct HeapItem {
    key_rank: usize, // which input table
    row: u32,
}

/// Merge tables that are each sorted under `opts` into one sorted table.
pub fn merge_sorted(tables: &[&Table], opts: &SortOptions) -> Result<Table> {
    if tables.is_empty() {
        return Err(crate::error::Error::invalid("merge_sorted of zero tables"));
    }
    if tables.len() == 1 {
        return Ok(tables[0].clone());
    }
    let cols: Vec<usize> = opts.keys.iter().map(|k| k.col).collect();
    let dirs: Vec<bool> = opts.keys.iter().map(|k| k.ascending).collect();
    let cmp = |a: &HeapItem, b: &HeapItem| -> Ordering {
        for (i, &c) in cols.iter().enumerate() {
            let ord = rows_cmp(
                tables[a.key_rank],
                a.row as usize,
                &[c],
                tables[b.key_rank],
                b.row as usize,
                &[c],
            );
            let ord = if dirs[i] { ord } else { ord.reverse() };
            if ord != Ordering::Equal {
                return ord;
            }
        }
        // tie-break on input rank for determinism
        a.key_rank.cmp(&b.key_rank)
    };

    struct Ord2<'a> {
        item: HeapItem,
        cmp: &'a dyn Fn(&HeapItem, &HeapItem) -> Ordering,
    }
    impl PartialEq for Ord2<'_> {
        fn eq(&self, other: &Self) -> bool {
            (self.cmp)(&self.item, &other.item) == Ordering::Equal
        }
    }
    impl Eq for Ord2<'_> {}
    impl PartialOrd for Ord2<'_> {
        fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
            Some(self.cmp(other))
        }
    }
    impl Ord for Ord2<'_> {
        fn cmp(&self, other: &Self) -> Ordering {
            (self.cmp)(&self.item, &other.item)
        }
    }

    let cmp_ref: &dyn Fn(&HeapItem, &HeapItem) -> Ordering = &cmp;
    let mut heap: BinaryHeap<Reverse<Ord2>> = BinaryHeap::new();
    for (k, t) in tables.iter().enumerate() {
        if t.num_rows() > 0 {
            heap.push(Reverse(Ord2 { item: HeapItem { key_rank: k, row: 0 }, cmp: cmp_ref }));
        }
    }
    // Collect (table, row) picks, then gather per input table preserving
    // pick order via a permutation over the concatenated table.
    let mut pick_table: Vec<u32> = Vec::new();
    let mut pick_row: Vec<u32> = Vec::new();
    while let Some(Reverse(top)) = heap.pop() {
        let HeapItem { key_rank, row } = top.item;
        pick_table.push(key_rank as u32);
        pick_row.push(row);
        if (row as usize) + 1 < tables[key_rank].num_rows() {
            heap.push(Reverse(Ord2 {
                item: HeapItem { key_rank, row: row + 1 },
                cmp: cmp_ref,
            }));
        }
    }
    // Build global indices into concat order.
    let mut base = vec![0u32; tables.len()];
    let mut acc = 0u32;
    for (k, t) in tables.iter().enumerate() {
        base[k] = acc;
        acc += t.num_rows() as u32;
    }
    let global: Vec<u32> = pick_table
        .iter()
        .zip(&pick_row)
        .map(|(&t, &r)| base[t as usize] + r)
        .collect();
    let concat = Table::concat(tables)?;
    Ok(concat.gather(&global))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::ops::sort::is_sorted;

    #[test]
    fn merges_sorted_runs() {
        let a = Table::from_columns(vec![("k", Column::from_i64(vec![1, 4, 7]))]).unwrap();
        let b = Table::from_columns(vec![("k", Column::from_i64(vec![2, 5, 8]))]).unwrap();
        let c = Table::from_columns(vec![("k", Column::from_i64(vec![3, 6]))]).unwrap();
        let m = merge_sorted(&[&a, &b, &c], &SortOptions::by(0)).unwrap();
        assert_eq!(
            m.column(0).unwrap().i64_values().unwrap(),
            &[1, 2, 3, 4, 5, 6, 7, 8]
        );
    }

    #[test]
    fn merge_with_duplicates_and_empty() {
        let a = Table::from_columns(vec![("k", Column::from_i64(vec![1, 1, 2]))]).unwrap();
        let b = Table::from_columns(vec![("k", Column::from_i64(vec![]))]).unwrap();
        let c = Table::from_columns(vec![("k", Column::from_i64(vec![1, 3]))]).unwrap();
        let m = merge_sorted(&[&a, &b, &c], &SortOptions::by(0)).unwrap();
        assert_eq!(m.column(0).unwrap().i64_values().unwrap(), &[1, 1, 1, 2, 3]);
        assert!(is_sorted(&m, &SortOptions::by(0)));
    }

    #[test]
    fn descending_merge() {
        let a = Table::from_columns(vec![("k", Column::from_i64(vec![9, 5, 1]))]).unwrap();
        let b = Table::from_columns(vec![("k", Column::from_i64(vec![8, 4]))]).unwrap();
        let m = merge_sorted(&[&a, &b], &SortOptions::by_desc(0)).unwrap();
        assert_eq!(m.column(0).unwrap().i64_values().unwrap(), &[9, 8, 5, 4, 1]);
    }
}

//! Filter / selection operators.

use super::kernels::{approx_row_bytes, gather_table};
use crate::column::Column;
use crate::error::{Error, Result};
use crate::executor::MorselPool;
use crate::table::Table;

/// Keep rows where `pred(row)` is true (slow generic path).
pub fn filter(t: &Table, pred: impl Fn(usize) -> bool + Sync) -> Table {
    filter_with_pool(t, pred, &MorselPool::disabled())
}

/// [`filter`] on a morsel pool: each morsel evaluates the predicate over
/// its row range into a local selection vector; the vectors concatenate
/// in morsel (= row) order, so the kept-row order — and hence the output
/// table — is identical to the serial pass.
pub fn filter_with_pool(
    t: &Table,
    pred: impl Fn(usize) -> bool + Sync,
    pool: &MorselPool,
) -> Table {
    let ranges = pool.ranges(t.num_rows(), approx_row_bytes(t));
    let chunks = pool.run(ranges.len(), |m| {
        let (start, len) = ranges[m];
        (start..start + len)
            .filter(|&r| pred(r))
            .map(|r| r as u32)
            .collect::<Vec<u32>>()
    });
    let mut idx = Vec::with_capacity(chunks.iter().map(Vec::len).sum());
    for ch in chunks {
        idx.extend(ch);
    }
    gather_table(t, &idx, pool)
}

/// Keep rows where a bool column is true (nulls drop) — the vectorized path.
pub fn filter_by_column(t: &Table, mask_col: usize) -> Result<Table> {
    filter_by_column_with_pool(t, mask_col, &MorselPool::disabled())
}

/// [`filter_by_column`] on a morsel pool (same selection-vector
/// composition as [`filter_with_pool`], with the mask column driving the
/// per-morsel inner loop).
pub fn filter_by_column_with_pool(
    t: &Table,
    mask_col: usize,
    pool: &MorselPool,
) -> Result<Table> {
    let col = t.column(mask_col)?;
    let mask = match col {
        Column::Bool(c) => c,
        other => {
            return Err(Error::Type(format!(
                "filter mask must be bool, got {}",
                other.dtype()
            )))
        }
    };
    let ranges = pool.ranges(t.num_rows(), approx_row_bytes(t));
    let chunks = pool.run(ranges.len(), |m| {
        let (start, len) = ranges[m];
        let mut sel = Vec::new();
        for r in start..start + len {
            if mask.values[r] && col.is_valid(r) {
                sel.push(r as u32);
            }
        }
        sel
    });
    let mut idx = Vec::with_capacity(chunks.iter().map(Vec::len).sum());
    for ch in chunks {
        idx.extend(ch);
    }
    Ok(gather_table(t, &idx, pool))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Value;

    #[test]
    fn filter_closure() {
        let t = Table::from_columns(vec![("k", Column::from_i64(vec![1, 2, 3, 4]))]).unwrap();
        let keys = t.column(0).unwrap().i64_values().unwrap().to_vec();
        let f = filter(&t, |r| keys[r] % 2 == 0);
        assert_eq!(f.column(0).unwrap().i64_values().unwrap(), &[2, 4]);
    }

    #[test]
    fn filter_mask_column() {
        let t = Table::from_columns(vec![
            ("k", Column::from_i64(vec![1, 2, 3])),
            ("m", Column::from_bools(vec![true, false, true])),
        ])
        .unwrap();
        let f = filter_by_column(&t, 1).unwrap();
        assert_eq!(f.num_rows(), 2);
        assert_eq!(f.value(1, 0).unwrap(), Value::Int64(3));
        assert!(filter_by_column(&t, 0).is_err());
    }
}

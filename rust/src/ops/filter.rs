//! Filter / selection operators.

use crate::column::Column;
use crate::error::{Error, Result};
use crate::table::Table;

/// Keep rows where `pred(row)` is true (slow generic path).
pub fn filter(t: &Table, pred: impl Fn(usize) -> bool) -> Table {
    let idx: Vec<u32> = (0..t.num_rows())
        .filter(|&r| pred(r))
        .map(|r| r as u32)
        .collect();
    t.gather(&idx)
}

/// Keep rows where a bool column is true (nulls drop) — the vectorized path.
pub fn filter_by_column(t: &Table, mask_col: usize) -> Result<Table> {
    let col = t.column(mask_col)?;
    let mask = match col {
        Column::Bool(c) => c,
        other => {
            return Err(Error::Type(format!(
                "filter mask must be bool, got {}",
                other.dtype()
            )))
        }
    };
    let mut idx = Vec::new();
    for (r, &m) in mask.values.iter().enumerate() {
        if m && col.is_valid(r) {
            idx.push(r as u32);
        }
    }
    Ok(t.gather(&idx))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Value;

    #[test]
    fn filter_closure() {
        let t = Table::from_columns(vec![("k", Column::from_i64(vec![1, 2, 3, 4]))]).unwrap();
        let keys = t.column(0).unwrap().i64_values().unwrap().to_vec();
        let f = filter(&t, |r| keys[r] % 2 == 0);
        assert_eq!(f.column(0).unwrap().i64_values().unwrap(), &[2, 4]);
    }

    #[test]
    fn filter_mask_column() {
        let t = Table::from_columns(vec![
            ("k", Column::from_i64(vec![1, 2, 3])),
            ("m", Column::from_bools(vec![true, false, true])),
        ])
        .unwrap();
        let f = filter_by_column(&t, 1).unwrap();
        assert_eq!(f.num_rows(), 2);
        assert_eq!(f.value(1, 0).unwrap(), Value::Int64(3));
        assert!(filter_by_column(&t, 0).is_err());
    }
}

//! Partitioners — the *auxiliary local operators* that precede every
//! shuffle (paper Fig 2: "partition" boxes).
//!
//! Hash partitioning runs the [`KeyHasher`] (PJRT Pallas kernel or native)
//! over the key columns and scatters rows to `p` output tables; range
//! partitioning (for distributed sort) routes by splitter comparison.

use super::kernels::{approx_row_bytes, row_hashes_range, rows_cmp, KeyHasher};
use crate::error::{Error, Result};
use crate::executor::MorselPool;
use crate::table::Table;

/// Split `t` into `p` tables by key hash: row `i` goes to partition
/// `hash(keys[i]) mod p`. Partition assignment is identical on every
/// worker (same hash function), which is what makes the distributed
/// operators correct.
pub fn partition_by_hash(
    t: &Table,
    key_cols: &[usize],
    p: usize,
    hasher: &dyn KeyHasher,
) -> Result<Vec<Table>> {
    partition_by_hash_with_pool(t, key_cols, p, hasher, &MorselPool::disabled())
}

/// [`partition_by_hash`] on a morsel pool: key hashing runs one columnar
/// batch kernel per morsel and each output partition gathers on its own
/// worker. The row→partition assignment and the stable within-partition
/// row order are pool-independent, so serial and parallel outputs are
/// identical tables.
pub fn partition_by_hash_with_pool(
    t: &Table,
    key_cols: &[usize],
    p: usize,
    hasher: &dyn KeyHasher,
    pool: &MorselPool,
) -> Result<Vec<Table>> {
    if p == 0 {
        return Err(Error::invalid("partition_by_hash: p must be > 0"));
    }
    if p == 1 {
        return Ok(vec![t.clone()]);
    }
    let ranges = pool.ranges(t.num_rows(), approx_row_bytes(t));
    let chunks = pool.run(ranges.len(), |m| {
        let (start, len) = ranges[m];
        row_hashes_range(t, key_cols, hasher, start, len)
    });
    let mut hashes: Vec<i64> = Vec::with_capacity(t.num_rows());
    for ch in chunks {
        hashes.extend(ch?);
    }
    // two-pass scatter: histogram then fill — avoids per-partition Vec grow.
    let mut counts = vec![0u32; p];
    let pids: Vec<u32> = hashes
        .iter()
        .map(|&h| (h as u64 % p as u64) as u32)
        .collect();
    for &pid in &pids {
        counts[pid as usize] += 1;
    }
    let mut offsets = vec![0u32; p + 1];
    for i in 0..p {
        offsets[i + 1] = offsets[i] + counts[i];
    }
    let mut order = vec![0u32; t.num_rows()];
    let mut cursor = offsets[..p].to_vec();
    for (row, &pid) in pids.iter().enumerate() {
        order[cursor[pid as usize] as usize] = row as u32;
        cursor[pid as usize] += 1;
    }
    Ok(pool.run(p, |i| {
        let slice = &order[offsets[i] as usize..offsets[i + 1] as usize];
        t.gather(slice)
    }))
}

/// Split `t` into `splitters.num_rows() + 1` tables by range: row goes to
/// the first partition whose splitter is ≥ the row key (splitters must be
/// sorted on the same key columns). Used by the distributed sample sort.
pub fn partition_by_range(
    t: &Table,
    key_cols: &[usize],
    splitters: &Table,
    splitter_cols: &[usize],
) -> Result<Vec<Table>> {
    partition_by_range_directed(t, key_cols, splitters, splitter_cols, &vec![true; key_cols.len()])
}

/// Shared argument check for the directed range partitioners.
fn check_range_args(key_cols: &[usize], splitter_cols: &[usize], dirs: &[bool]) -> Result<()> {
    if dirs.len() != key_cols.len() || splitter_cols.len() != key_cols.len() {
        return Err(Error::invalid(
            "partition_by_range: key/splitter/direction lists must have equal length",
        ));
    }
    Ok(())
}

/// Directed multi-key comparison of `t[row]` against `splitters[srow]` —
/// the one definition both range partitioners route through, so the
/// spreading variant's bucket bounds stay exactly equivalent to the
/// plain router's.
#[allow(clippy::too_many_arguments)]
fn cmp_row_vs_splitter(
    t: &Table,
    row: usize,
    key_cols: &[usize],
    splitters: &Table,
    srow: usize,
    splitter_cols: &[usize],
    dirs: &[bool],
) -> std::cmp::Ordering {
    for ((&kc, &sc), &asc) in key_cols.iter().zip(splitter_cols).zip(dirs) {
        let mut ord = rows_cmp(t, row, &[kc], splitters, srow, &[sc]);
        if !asc {
            ord = ord.reverse();
        }
        if ord != std::cmp::Ordering::Equal {
            return ord;
        }
    }
    std::cmp::Ordering::Equal
}

/// First splitter index whose row is ≥ `t[row]` under the directed order
/// (= the plain router's destination bucket; ties land here).
fn range_lower_bound(
    t: &Table,
    row: usize,
    key_cols: &[usize],
    splitters: &Table,
    splitter_cols: &[usize],
    dirs: &[bool],
) -> usize {
    let (mut lo, mut hi) = (0usize, splitters.num_rows());
    while lo < hi {
        let mid = (lo + hi) / 2;
        match cmp_row_vs_splitter(t, row, key_cols, splitters, mid, splitter_cols, dirs) {
            std::cmp::Ordering::Greater => lo = mid + 1,
            _ => hi = mid,
        }
    }
    lo
}

/// [`partition_by_range`] with a per-key sort direction (`dirs[i]` true =
/// ascending): "≥ the row key" is evaluated under the directed order, so
/// descending / mixed-direction distributed sorts route correctly.
/// `dirs.len()` must equal `key_cols.len()`.
pub fn partition_by_range_directed(
    t: &Table,
    key_cols: &[usize],
    splitters: &Table,
    splitter_cols: &[usize],
    dirs: &[bool],
) -> Result<Vec<Table>> {
    check_range_args(key_cols, splitter_cols, dirs)?;
    let p = splitters.num_rows() + 1;
    let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); p];
    for row in 0..t.num_rows() {
        let lo = range_lower_bound(t, row, key_cols, splitters, splitter_cols, dirs);
        buckets[lo].push(row as u32);
    }
    Ok(buckets.into_iter().map(|b| t.gather(&b)).collect())
}

/// [`partition_by_range_directed`] with **tie spreading** — the routing
/// rule of the skew-aware sample sort (DESIGN.md §8). When the splitter
/// table contains duplicate rows (the splitter derivation repeats a hot
/// key once per bucket-worth of sampled mass), every bucket bounded by an
/// equal splitter is a legal destination for a tied row: rows strictly
/// below the key still land strictly below, rows strictly above strictly
/// above, so the rank-ordered concatenation stays globally sorted no
/// matter which bucket in the tie range each tied row picks. This
/// partitioner round-robins tied rows across that contiguous bucket
/// range, splitting a hot key over several ranks instead of piling it
/// into the lowest one.
///
/// Only valid for non-stable sorts: spreading interleaves equal rows from
/// different source ranks, so their original relative order is lost.
///
/// Also returns the per-bucket row counts the **non-spreading** router
/// would have produced (every tie to its `lo` bucket) — the baseline of
/// the skew balance report, computed in the same pass so the caller
/// never needs a second full partition.
pub fn partition_by_range_directed_spread(
    t: &Table,
    key_cols: &[usize],
    splitters: &Table,
    splitter_cols: &[usize],
    dirs: &[bool],
) -> Result<(Vec<Table>, Vec<i64>)> {
    check_range_args(key_cols, splitter_cols, dirs)?;
    let ns = splitters.num_rows();
    let p = ns + 1;
    let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); p];
    let mut plain_counts = vec![0i64; p];
    // Round-robin counter per tie range (lo..=hi); ranges are few (one
    // per run of duplicate splitters), so a small map suffices.
    let mut spin: std::collections::BTreeMap<(usize, usize), usize> =
        std::collections::BTreeMap::new();
    for row in 0..t.num_rows() {
        // lo: first splitter ≥ the row key (the plain router's bucket —
        // rows below any splitter get lo == hi == bucket).
        let lo = range_lower_bound(t, row, key_cols, splitters, splitter_cols, dirs);
        plain_counts[lo] += 1;
        // hi: first splitter strictly > the row key; buckets lo..=hi are
        // all bounded below by keys ≤ row and above by keys ≥ row.
        let (mut a, mut b) = (lo, ns);
        while a < b {
            let mid = (a + b) / 2;
            match cmp_row_vs_splitter(t, row, key_cols, splitters, mid, splitter_cols, dirs) {
                std::cmp::Ordering::Less => b = mid,
                _ => a = mid + 1,
            }
        }
        let hi = a;
        let width = hi - lo + 1;
        let dest = if width == 1 {
            lo
        } else {
            let c = spin.entry((lo, hi)).or_insert(0);
            let d = lo + *c % width;
            *c += 1;
            d
        };
        buckets[dest].push(row as u32);
    }
    let parts = buckets.into_iter().map(|b| t.gather(&b)).collect();
    Ok((parts, plain_counts))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::ops::NativeHasher;

    fn t(n: usize) -> Table {
        crate::datagen::uniform_table(3, n, 0.9)
    }

    #[test]
    fn hash_partition_covers_all_rows() {
        let tab = t(10_000);
        let parts = partition_by_hash(&tab, &[0], 8, &NativeHasher).unwrap();
        assert_eq!(parts.len(), 8);
        let total: usize = parts.iter().map(|p| p.num_rows()).sum();
        assert_eq!(total, 10_000);
        // roughly balanced under uniform keys
        for p in &parts {
            assert!(p.num_rows() > 800, "unbalanced: {}", p.num_rows());
        }
    }

    #[test]
    fn same_key_same_partition() {
        let tab = Table::from_columns(vec![(
            "k",
            Column::from_i64(vec![7, 7, 7, 13, 13, 7]),
        )])
        .unwrap();
        let parts = partition_by_hash(&tab, &[0], 4, &NativeHasher).unwrap();
        // all 7s land together, all 13s land together
        let with_7: Vec<usize> = parts
            .iter()
            .enumerate()
            .filter(|(_, p)| p.column(0).unwrap().i64_values().unwrap().contains(&7))
            .map(|(i, _)| i)
            .collect();
        assert_eq!(with_7.len(), 1);
        assert_eq!(
            parts[with_7[0]]
                .column(0)
                .unwrap()
                .i64_values()
                .unwrap()
                .iter()
                .filter(|&&k| k == 7)
                .count(),
            4
        );
    }

    #[test]
    fn p_one_is_identity() {
        let tab = t(100);
        let parts = partition_by_hash(&tab, &[0], 1, &NativeHasher).unwrap();
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0], tab);
    }

    #[test]
    fn range_partition_routes_by_splitters() {
        let tab = Table::from_columns(vec![("k", Column::from_i64(vec![5, 15, 25, 10, 20]))])
            .unwrap();
        let splitters =
            Table::from_columns(vec![("k", Column::from_i64(vec![10, 20]))]).unwrap();
        let parts = partition_by_range(&tab, &[0], &splitters, &[0]).unwrap();
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0].column(0).unwrap().i64_values().unwrap(), &[5, 10]); // ≤10
        assert_eq!(parts[1].column(0).unwrap().i64_values().unwrap(), &[15, 20]); // ≤20
        assert_eq!(parts[2].column(0).unwrap().i64_values().unwrap(), &[25]); // >20
    }

    #[test]
    fn range_partition_directed_descending() {
        let tab = Table::from_columns(vec![("k", Column::from_i64(vec![5, 15, 25, 10, 20]))])
            .unwrap();
        // splitters sorted under the DESCENDING order
        let splitters =
            Table::from_columns(vec![("k", Column::from_i64(vec![20, 10]))]).unwrap();
        let parts =
            partition_by_range_directed(&tab, &[0], &splitters, &[0], &[false]).unwrap();
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0].column(0).unwrap().i64_values().unwrap(), &[25, 20]); // ≥20
        assert_eq!(parts[1].column(0).unwrap().i64_values().unwrap(), &[15, 10]); // ≥10
        assert_eq!(parts[2].column(0).unwrap().i64_values().unwrap(), &[5]); // rest
        // direction-list length is validated
        assert!(partition_by_range_directed(&tab, &[0], &splitters, &[0], &[]).is_err());
    }

    #[test]
    fn spread_partition_balances_ties_and_keeps_order() {
        // 80 rows of the hot key 10, a few rows around it; duplicate
        // splitters [10, 10, 20] open buckets 0..=2 for the ties.
        let mut keys = vec![5, 25, 15];
        keys.extend(vec![10i64; 80]);
        let tab = Table::from_columns(vec![("k", Column::from_i64(keys))]).unwrap();
        let splitters =
            Table::from_columns(vec![("k", Column::from_i64(vec![10, 10, 20]))]).unwrap();
        let (parts, plain_counts) =
            partition_by_range_directed_spread(&tab, &[0], &splitters, &[0], &[true]).unwrap();
        assert_eq!(parts.len(), 4);
        // the baseline counts route every tie to its lowest bucket
        assert_eq!(plain_counts, vec![81, 0, 1, 1]);
        assert_eq!(parts.iter().map(|p| p.num_rows()).sum::<usize>(), 83);
        // ties spread evenly over buckets 0..=2, none in bucket 3
        for b in 0..3 {
            let tens = parts[b]
                .column(0)
                .unwrap()
                .i64_values()
                .unwrap()
                .iter()
                .filter(|&&k| k == 10)
                .count();
            assert!((26..=28).contains(&tens), "bucket {b} got {tens} ties");
        }
        assert!(!parts[3].column(0).unwrap().i64_values().unwrap().contains(&10));
        // non-tied rows still route by range: 5→0, 15→2, 25→3
        assert!(parts[0].column(0).unwrap().i64_values().unwrap().contains(&5));
        assert!(parts[2].column(0).unwrap().i64_values().unwrap().contains(&15));
        assert!(parts[3].column(0).unwrap().i64_values().unwrap().contains(&25));
        // the global order invariant survives: max(bucket i) ≤ min(bucket i+1)
        for i in 0..3 {
            let hi = parts[i].column(0).unwrap().i64_values().unwrap().iter().max();
            let lo = parts[i + 1].column(0).unwrap().i64_values().unwrap().iter().min();
            if let (Some(hi), Some(lo)) = (hi, lo) {
                assert!(hi <= lo, "order broken between buckets {i} and {}", i + 1);
            }
        }
    }

    #[test]
    fn spread_without_ties_matches_plain() {
        // even keys, odd splitters: no row ever equals a splitter, so the
        // tie range is always a single bucket and routing is identical
        let keys: Vec<i64> = (0..2_000).map(|i| i * 2).collect();
        let tab = Table::from_columns(vec![("k", Column::from_i64(keys))]).unwrap();
        let splitters = Table::from_columns(vec![(
            "k",
            Column::from_i64(vec![501, 1001, 1501]),
        )])
        .unwrap();
        let plain = partition_by_range(&tab, &[0], &splitters, &[0]).unwrap();
        let (spread, plain_counts) =
            partition_by_range_directed_spread(&tab, &[0], &splitters, &[0], &[true]).unwrap();
        assert_eq!(plain, spread);
        let counts: Vec<i64> = plain.iter().map(|p| p.num_rows() as i64).collect();
        assert_eq!(plain_counts, counts);
    }

    #[test]
    fn range_partition_ordering_invariant() {
        // every key in partition i ≤ every key in partition i+1
        let tab = t(5_000);
        let splitters = Table::from_columns(vec![(
            "k",
            Column::from_i64(vec![1000, 2500, 4000]),
        )])
        .unwrap();
        let parts = partition_by_range(&tab, &[0], &splitters, &[0]).unwrap();
        let maxes: Vec<i64> = parts
            .iter()
            .map(|p| p.column(0).unwrap().i64_values().unwrap().iter().copied().max().unwrap_or(i64::MIN))
            .collect();
        let mins: Vec<i64> = parts
            .iter()
            .map(|p| p.column(0).unwrap().i64_values().unwrap().iter().copied().min().unwrap_or(i64::MAX))
            .collect();
        for i in 0..parts.len() - 1 {
            assert!(maxes[i] <= mins[i + 1]);
        }
    }
}

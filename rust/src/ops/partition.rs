//! Partitioners — the *auxiliary local operators* that precede every
//! shuffle (paper Fig 2: "partition" boxes).
//!
//! Hash partitioning runs the [`KeyHasher`] (PJRT Pallas kernel or native)
//! over the key columns and scatters rows to `p` output tables; range
//! partitioning (for distributed sort) routes by splitter comparison.

use super::kernels::{row_hashes, rows_cmp, KeyHasher};
use crate::error::{Error, Result};
use crate::table::Table;

/// Split `t` into `p` tables by key hash: row `i` goes to partition
/// `hash(keys[i]) mod p`. Partition assignment is identical on every
/// worker (same hash function), which is what makes the distributed
/// operators correct.
pub fn partition_by_hash(
    t: &Table,
    key_cols: &[usize],
    p: usize,
    hasher: &dyn KeyHasher,
) -> Result<Vec<Table>> {
    if p == 0 {
        return Err(Error::invalid("partition_by_hash: p must be > 0"));
    }
    if p == 1 {
        return Ok(vec![t.clone()]);
    }
    let hashes = row_hashes(t, key_cols, hasher)?;
    // two-pass scatter: histogram then fill — avoids per-partition Vec grow.
    let mut counts = vec![0u32; p];
    let pids: Vec<u32> = hashes
        .iter()
        .map(|&h| (h as u64 % p as u64) as u32)
        .collect();
    for &pid in &pids {
        counts[pid as usize] += 1;
    }
    let mut offsets = vec![0u32; p + 1];
    for i in 0..p {
        offsets[i + 1] = offsets[i] + counts[i];
    }
    let mut order = vec![0u32; t.num_rows()];
    let mut cursor = offsets[..p].to_vec();
    for (row, &pid) in pids.iter().enumerate() {
        order[cursor[pid as usize] as usize] = row as u32;
        cursor[pid as usize] += 1;
    }
    let mut out = Vec::with_capacity(p);
    for i in 0..p {
        let slice = &order[offsets[i] as usize..offsets[i + 1] as usize];
        out.push(t.gather(slice));
    }
    Ok(out)
}

/// Split `t` into `splitters.num_rows() + 1` tables by range: row goes to
/// the first partition whose splitter is ≥ the row key (splitters must be
/// sorted on the same key columns). Used by the distributed sample sort.
pub fn partition_by_range(
    t: &Table,
    key_cols: &[usize],
    splitters: &Table,
    splitter_cols: &[usize],
) -> Result<Vec<Table>> {
    partition_by_range_directed(t, key_cols, splitters, splitter_cols, &vec![true; key_cols.len()])
}

/// [`partition_by_range`] with a per-key sort direction (`dirs[i]` true =
/// ascending): "≥ the row key" is evaluated under the directed order, so
/// descending / mixed-direction distributed sorts route correctly.
/// `dirs.len()` must equal `key_cols.len()`.
pub fn partition_by_range_directed(
    t: &Table,
    key_cols: &[usize],
    splitters: &Table,
    splitter_cols: &[usize],
    dirs: &[bool],
) -> Result<Vec<Table>> {
    if dirs.len() != key_cols.len() || splitter_cols.len() != key_cols.len() {
        return Err(Error::invalid(
            "partition_by_range: key/splitter/direction lists must have equal length",
        ));
    }
    let cmp_directed = |row: usize, srow: usize| -> std::cmp::Ordering {
        for ((&kc, &sc), &asc) in key_cols.iter().zip(splitter_cols).zip(dirs) {
            let mut ord = rows_cmp(t, row, &[kc], splitters, srow, &[sc]);
            if !asc {
                ord = ord.reverse();
            }
            if ord != std::cmp::Ordering::Equal {
                return ord;
            }
        }
        std::cmp::Ordering::Equal
    };
    let p = splitters.num_rows() + 1;
    let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); p];
    for row in 0..t.num_rows() {
        // binary search over splitters
        let (mut lo, mut hi) = (0usize, splitters.num_rows());
        while lo < hi {
            let mid = (lo + hi) / 2;
            match cmp_directed(row, mid) {
                std::cmp::Ordering::Greater => lo = mid + 1,
                _ => hi = mid,
            }
        }
        buckets[lo].push(row as u32);
    }
    Ok(buckets.into_iter().map(|b| t.gather(&b)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::ops::NativeHasher;

    fn t(n: usize) -> Table {
        crate::datagen::uniform_table(3, n, 0.9)
    }

    #[test]
    fn hash_partition_covers_all_rows() {
        let tab = t(10_000);
        let parts = partition_by_hash(&tab, &[0], 8, &NativeHasher).unwrap();
        assert_eq!(parts.len(), 8);
        let total: usize = parts.iter().map(|p| p.num_rows()).sum();
        assert_eq!(total, 10_000);
        // roughly balanced under uniform keys
        for p in &parts {
            assert!(p.num_rows() > 800, "unbalanced: {}", p.num_rows());
        }
    }

    #[test]
    fn same_key_same_partition() {
        let tab = Table::from_columns(vec![(
            "k",
            Column::from_i64(vec![7, 7, 7, 13, 13, 7]),
        )])
        .unwrap();
        let parts = partition_by_hash(&tab, &[0], 4, &NativeHasher).unwrap();
        // all 7s land together, all 13s land together
        let with_7: Vec<usize> = parts
            .iter()
            .enumerate()
            .filter(|(_, p)| p.column(0).unwrap().i64_values().unwrap().contains(&7))
            .map(|(i, _)| i)
            .collect();
        assert_eq!(with_7.len(), 1);
        assert_eq!(
            parts[with_7[0]]
                .column(0)
                .unwrap()
                .i64_values()
                .unwrap()
                .iter()
                .filter(|&&k| k == 7)
                .count(),
            4
        );
    }

    #[test]
    fn p_one_is_identity() {
        let tab = t(100);
        let parts = partition_by_hash(&tab, &[0], 1, &NativeHasher).unwrap();
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0], tab);
    }

    #[test]
    fn range_partition_routes_by_splitters() {
        let tab = Table::from_columns(vec![("k", Column::from_i64(vec![5, 15, 25, 10, 20]))])
            .unwrap();
        let splitters =
            Table::from_columns(vec![("k", Column::from_i64(vec![10, 20]))]).unwrap();
        let parts = partition_by_range(&tab, &[0], &splitters, &[0]).unwrap();
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0].column(0).unwrap().i64_values().unwrap(), &[5, 10]); // ≤10
        assert_eq!(parts[1].column(0).unwrap().i64_values().unwrap(), &[15, 20]); // ≤20
        assert_eq!(parts[2].column(0).unwrap().i64_values().unwrap(), &[25]); // >20
    }

    #[test]
    fn range_partition_directed_descending() {
        let tab = Table::from_columns(vec![("k", Column::from_i64(vec![5, 15, 25, 10, 20]))])
            .unwrap();
        // splitters sorted under the DESCENDING order
        let splitters =
            Table::from_columns(vec![("k", Column::from_i64(vec![20, 10]))]).unwrap();
        let parts =
            partition_by_range_directed(&tab, &[0], &splitters, &[0], &[false]).unwrap();
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0].column(0).unwrap().i64_values().unwrap(), &[25, 20]); // ≥20
        assert_eq!(parts[1].column(0).unwrap().i64_values().unwrap(), &[15, 10]); // ≥10
        assert_eq!(parts[2].column(0).unwrap().i64_values().unwrap(), &[5]); // rest
        // direction-list length is validated
        assert!(partition_by_range_directed(&tab, &[0], &splitters, &[0], &[]).is_err());
    }

    #[test]
    fn range_partition_ordering_invariant() {
        // every key in partition i ≤ every key in partition i+1
        let tab = t(5_000);
        let splitters = Table::from_columns(vec![(
            "k",
            Column::from_i64(vec![1000, 2500, 4000]),
        )])
        .unwrap();
        let parts = partition_by_range(&tab, &[0], &splitters, &[0]).unwrap();
        let maxes: Vec<i64> = parts
            .iter()
            .map(|p| p.column(0).unwrap().i64_values().unwrap().iter().copied().max().unwrap_or(i64::MIN))
            .collect();
        let mins: Vec<i64> = parts
            .iter()
            .map(|p| p.column(0).unwrap().i64_values().unwrap().iter().copied().min().unwrap_or(i64::MAX))
            .collect();
        for i in 0..parts.len() - 1 {
            assert!(maxes[i] <= mins[i + 1]);
        }
    }
}

//! Row-level kernels shared by the key-based operators: per-row key
//! hashing, multi-column row comparison and equality.
//!
//! Key hashing is *the* per-row compute hot-spot (every shuffle, hash join
//! and hash groupby runs it over all rows). The [`KeyHasher`] trait makes
//! the execution path pluggable: [`NativeHasher`] (pure Rust) or
//! [`crate::runtime::PjrtHasher`] (the L1 Pallas kernel compiled AOT and
//! executed through PJRT). Both compute the identical splitmix64 function.

use crate::column::Column;
use crate::error::{Error, Result};
use crate::table::Table;
use crate::util::hash::{combine, hash64};
use std::cmp::Ordering;

/// Hash sentinel for null slots (any fixed odd constant works; it must just
/// be consistent across workers).
const NULL_HASH: i64 = 0x6b5f_c1a7_1234_5677u64 as i64;

/// Pluggable per-row key-hash execution.
pub trait KeyHasher: Send + Sync {
    /// Hash the i64 key slice into `out` (both sides implement splitmix64).
    fn hash_i64(&self, keys: &[i64], out: &mut [i64]) -> Result<()>;

    /// Human-readable label for reports ("native", "pjrt").
    fn label(&self) -> &'static str;
}

/// Pure-Rust splitmix64 hasher (bit-identical to the Pallas kernel).
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeHasher;

impl KeyHasher for NativeHasher {
    fn hash_i64(&self, keys: &[i64], out: &mut [i64]) -> Result<()> {
        crate::util::hash::hash64_slice(keys, out);
        Ok(())
    }
    fn label(&self) -> &'static str {
        "native"
    }
}

/// Per-row hashes of one column (nulls hash to a fixed sentinel).
fn column_hashes(col: &Column, hasher: &dyn KeyHasher, out: &mut [i64]) -> Result<()> {
    match col {
        Column::Int64(c) => hasher.hash_i64(&c.values, out)?,
        Column::Float64(c) => {
            // Hash the bit pattern; canonicalize -0.0 and NaNs first.
            let bits: Vec<i64> = c
                .values
                .iter()
                .map(|&f| {
                    let f = if f == 0.0 { 0.0 } else { f };
                    let f = if f.is_nan() { f64::NAN } else { f };
                    f.to_bits() as i64
                })
                .collect();
            hasher.hash_i64(&bits, out)?;
        }
        Column::Bool(c) => {
            let bits: Vec<i64> = c.values.iter().map(|&b| b as i64).collect();
            hasher.hash_i64(&bits, out)?;
        }
        Column::Utf8(c) => {
            // FNV-1a over bytes, then one splitmix64 avalanche round so the
            // partitioner sees well-mixed high bits.
            for (i, o) in out.iter_mut().enumerate() {
                let s = c.get(i);
                let mut h = 0xcbf29ce484222325u64;
                for &b in s.as_bytes() {
                    h = (h ^ b as u64).wrapping_mul(0x100000001b3);
                }
                *o = hash64(h as i64);
            }
        }
    }
    // Null slots overwrite with the sentinel.
    if let Some(v) = col.validity() {
        for (i, o) in out.iter_mut().enumerate() {
            if !v.get(i) {
                *o = NULL_HASH;
            }
        }
    }
    Ok(())
}

/// Per-row combined hash over multiple key columns.
pub fn row_hashes(t: &Table, key_cols: &[usize], hasher: &dyn KeyHasher) -> Result<Vec<i64>> {
    if key_cols.is_empty() {
        return Err(Error::invalid("row_hashes: empty key column list"));
    }
    let n = t.num_rows();
    let mut acc = vec![0i64; n];
    column_hashes(t.column(key_cols[0])?, hasher, &mut acc)?;
    if key_cols.len() > 1 {
        let mut tmp = vec![0i64; n];
        for &kc in &key_cols[1..] {
            column_hashes(t.column(kc)?, hasher, &mut tmp)?;
            for (a, &b) in acc.iter_mut().zip(&tmp) {
                *a = combine(*a, b);
            }
        }
    }
    Ok(acc)
}

/// Row equality on key columns across two tables (SQL semantics for the
/// hash path: null == null so nulls group together; join kernels that need
/// `NULL != NULL` filter separately).
pub fn rows_equal(
    left: &Table,
    lrow: usize,
    lcols: &[usize],
    right: &Table,
    rrow: usize,
    rcols: &[usize],
) -> bool {
    debug_assert_eq!(lcols.len(), rcols.len());
    for (&lc, &rc) in lcols.iter().zip(rcols) {
        let a = &left.columns()[lc];
        let b = &right.columns()[rc];
        let av = a.is_valid(lrow);
        let bv = b.is_valid(rrow);
        if av != bv {
            return false;
        }
        if !av {
            continue; // both null
        }
        let eq = match (a, b) {
            (Column::Int64(x), Column::Int64(y)) => x.values[lrow] == y.values[rrow],
            (Column::Float64(x), Column::Float64(y)) => x.values[lrow] == y.values[rrow],
            (Column::Bool(x), Column::Bool(y)) => x.values[lrow] == y.values[rrow],
            (Column::Utf8(x), Column::Utf8(y)) => x.get(lrow) == y.get(rrow),
            _ => false,
        };
        if !eq {
            return false;
        }
    }
    true
}

/// Row ordering on key columns across two tables (nulls first).
pub fn rows_cmp(
    left: &Table,
    lrow: usize,
    lcols: &[usize],
    right: &Table,
    rrow: usize,
    rcols: &[usize],
) -> Ordering {
    for (&lc, &rc) in lcols.iter().zip(rcols) {
        let a = &left.columns()[lc];
        let b = &right.columns()[rc];
        let av = a.is_valid(lrow);
        let bv = b.is_valid(rrow);
        let ord = match (av, bv) {
            (false, false) => Ordering::Equal,
            (false, true) => Ordering::Less,
            (true, false) => Ordering::Greater,
            (true, true) => match (a, b) {
                (Column::Int64(x), Column::Int64(y)) => x.values[lrow].cmp(&y.values[rrow]),
                (Column::Float64(x), Column::Float64(y)) => x.values[lrow]
                    .partial_cmp(&y.values[rrow])
                    .unwrap_or(Ordering::Equal),
                (Column::Bool(x), Column::Bool(y)) => x.values[lrow].cmp(&y.values[rrow]),
                (Column::Utf8(x), Column::Utf8(y)) => x.get(lrow).cmp(y.get(rrow)),
                _ => Ordering::Equal,
            },
        };
        if ord != Ordering::Equal {
            return ord;
        }
    }
    Ordering::Equal
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> Table {
        Table::from_columns(vec![
            ("k", Column::from_opt_i64(&[Some(1), Some(2), None, Some(1)])),
            ("s", Column::from_strings(&["a", "b", "c", "a"])),
        ])
        .unwrap()
    }

    #[test]
    fn hashes_consistent_for_equal_rows() {
        let tab = t();
        let hs = row_hashes(&tab, &[0, 1], &NativeHasher).unwrap();
        assert_eq!(hs[0], hs[3]); // (1,"a") twice
        assert_ne!(hs[0], hs[1]);
    }

    #[test]
    fn null_rows_hash_to_sentinel_consistently() {
        let a = Table::from_columns(vec![("k", Column::from_opt_i64(&[None]))]).unwrap();
        let b = Table::from_columns(vec![("k", Column::from_opt_i64(&[None, Some(3)]))]).unwrap();
        let ha = row_hashes(&a, &[0], &NativeHasher).unwrap();
        let hb = row_hashes(&b, &[0], &NativeHasher).unwrap();
        assert_eq!(ha[0], hb[0]);
        assert_ne!(hb[0], hb[1]);
    }

    #[test]
    fn equality_and_order() {
        let tab = t();
        assert!(rows_equal(&tab, 0, &[0, 1], &tab, 3, &[0, 1]));
        assert!(!rows_equal(&tab, 0, &[0, 1], &tab, 1, &[0, 1]));
        // null == null under grouping semantics
        assert!(rows_equal(&tab, 2, &[0], &tab, 2, &[0]));
        assert_eq!(rows_cmp(&tab, 0, &[0], &tab, 1, &[0]), Ordering::Less);
        // nulls sort first
        assert_eq!(rows_cmp(&tab, 2, &[0], &tab, 0, &[0]), Ordering::Less);
    }

    #[test]
    fn float_hash_canonicalizes_zero() {
        let tab = Table::from_columns(vec![("f", Column::from_f64(vec![0.0, -0.0]))]).unwrap();
        let hs = row_hashes(&tab, &[0], &NativeHasher).unwrap();
        assert_eq!(hs[0], hs[1]);
    }
}

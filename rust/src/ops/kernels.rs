//! Row-level kernels shared by the key-based operators: per-row key
//! hashing, multi-column row comparison and equality.
//!
//! Key hashing is *the* per-row compute hot-spot (every shuffle, hash join
//! and hash groupby runs it over all rows). The [`KeyHasher`] trait makes
//! the execution path pluggable: [`NativeHasher`] (pure Rust) or
//! [`crate::runtime::PjrtHasher`] (the L1 Pallas kernel compiled AOT and
//! executed through PJRT). Both compute the identical splitmix64 function.

use crate::column::Column;
use crate::error::{Error, Result};
use crate::table::Table;
use crate::util::hash::{combine, hash64};
use std::cmp::Ordering;

/// Hash sentinel for null slots (any fixed odd constant works; it must just
/// be consistent across workers).
const NULL_HASH: i64 = 0x6b5f_c1a7_1234_5677u64 as i64;

/// Pluggable per-row key-hash execution.
pub trait KeyHasher: Send + Sync {
    /// Hash the i64 key slice into `out` (both sides implement splitmix64).
    fn hash_i64(&self, keys: &[i64], out: &mut [i64]) -> Result<()>;

    /// Human-readable label for reports ("native", "pjrt").
    fn label(&self) -> &'static str;
}

/// Pure-Rust splitmix64 hasher (bit-identical to the Pallas kernel).
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeHasher;

impl KeyHasher for NativeHasher {
    fn hash_i64(&self, keys: &[i64], out: &mut [i64]) -> Result<()> {
        crate::util::hash::hash64_slice(keys, out);
        Ok(())
    }
    fn label(&self) -> &'static str {
        "native"
    }
}

/// Hashes of one column over the row range `start..start + out.len()`
/// (nulls hash to a fixed sentinel). Batch-columnar: every dtype hashes a
/// contiguous value slice — the Utf8 path walks the offsets/data buffers
/// directly rather than materializing (and UTF-8-validating) one `&str`
/// per row, so a morsel runs one vectorized inner loop per column.
fn column_hashes_range(
    col: &Column,
    hasher: &dyn KeyHasher,
    start: usize,
    out: &mut [i64],
) -> Result<()> {
    let len = out.len();
    match col {
        Column::Int64(c) => hasher.hash_i64(&c.values[start..start + len], out)?,
        Column::Float64(c) => {
            // Hash the bit pattern; canonicalize -0.0 and NaNs first.
            let bits: Vec<i64> = c.values[start..start + len]
                .iter()
                .map(|&f| {
                    let f = if f == 0.0 { 0.0 } else { f };
                    let f = if f.is_nan() { f64::NAN } else { f };
                    f.to_bits() as i64
                })
                .collect();
            hasher.hash_i64(&bits, out)?;
        }
        Column::Bool(c) => {
            let bits: Vec<i64> =
                c.values[start..start + len].iter().map(|&b| b as i64).collect();
            hasher.hash_i64(&bits, out)?;
        }
        Column::Utf8(c) => {
            // FNV-1a over the raw byte slice, then one splitmix64
            // avalanche round so the partitioner sees well-mixed high
            // bits. Identical bytes ⇒ identical hash, so skipping the
            // per-row str conversion cannot change any result.
            for (i, o) in out.iter_mut().enumerate() {
                let row = start + i;
                let lo = c.offsets[row] as usize;
                let hi = c.offsets[row + 1] as usize;
                let mut h = 0xcbf29ce484222325u64;
                for &b in &c.data[lo..hi] {
                    h = (h ^ b as u64).wrapping_mul(0x100000001b3);
                }
                *o = hash64(h as i64);
            }
        }
    }
    // Null slots overwrite with the sentinel.
    if let Some(v) = col.validity() {
        for (i, o) in out.iter_mut().enumerate() {
            if !v.get(start + i) {
                *o = NULL_HASH;
            }
        }
    }
    Ok(())
}

/// Per-row combined hash over multiple key columns.
pub fn row_hashes(t: &Table, key_cols: &[usize], hasher: &dyn KeyHasher) -> Result<Vec<i64>> {
    row_hashes_range(t, key_cols, hasher, 0, t.num_rows())
}

/// [`row_hashes`] over the row range `start..start + len` — the morsel
/// form: each worker hashes its own range and the concatenation over
/// ascending ranges equals the whole-table pass bit for bit.
pub fn row_hashes_range(
    t: &Table,
    key_cols: &[usize],
    hasher: &dyn KeyHasher,
    start: usize,
    len: usize,
) -> Result<Vec<i64>> {
    if key_cols.is_empty() {
        return Err(Error::invalid("row_hashes: empty key column list"));
    }
    let mut acc = vec![0i64; len];
    column_hashes_range(t.column(key_cols[0])?, hasher, start, &mut acc)?;
    if key_cols.len() > 1 {
        let mut tmp = vec![0i64; len];
        for &kc in &key_cols[1..] {
            column_hashes_range(t.column(kc)?, hasher, start, &mut tmp)?;
            for (a, &b) in acc.iter_mut().zip(&tmp) {
                *a = combine(*a, b);
            }
        }
    }
    Ok(acc)
}

/// Dictionary-encode a string column: distinct byte-strings get dense
/// codes in first-occurrence order, null rows get code `-1`. Grouping or
/// probing on the codes is exactly grouping/probing on the strings (equal
/// bytes ⇔ equal code), which turns the string-keyed groupby/join inner
/// loops into the i64 fast path.
pub fn utf8_dict_encode(
    c: &crate::column::StringColumn,
) -> (crate::util::hash::FastMap<&[u8], i64>, Vec<i64>) {
    let n = c.offsets.len().saturating_sub(1);
    let mut dict: crate::util::hash::FastMap<&[u8], i64> =
        crate::util::hash::fast_map_with_capacity(n);
    let mut codes = Vec::with_capacity(n);
    for row in 0..n {
        if let Some(v) = &c.validity {
            if !v.get(row) {
                codes.push(-1);
                continue;
            }
        }
        let bytes = &c.data[c.offsets[row] as usize..c.offsets[row + 1] as usize];
        let next = dict.len() as i64;
        codes.push(*dict.entry(bytes).or_insert(next));
    }
    (dict, codes)
}

/// Probe-side codes against a build-side dictionary from
/// [`utf8_dict_encode`]: null rows and strings absent from the dictionary
/// both get `-1` (a join probe treats either as "no match").
pub fn utf8_dict_lookup(
    c: &crate::column::StringColumn,
    dict: &crate::util::hash::FastMap<&[u8], i64>,
) -> Vec<i64> {
    let n = c.offsets.len().saturating_sub(1);
    let mut codes = Vec::with_capacity(n);
    for row in 0..n {
        if let Some(v) = &c.validity {
            if !v.get(row) {
                codes.push(-1);
                continue;
            }
        }
        let bytes = &c.data[c.offsets[row] as usize..c.offsets[row + 1] as usize];
        codes.push(dict.get(bytes).copied().unwrap_or(-1));
    }
    codes
}

/// Average bytes per row — the morsel sizing estimate
/// ([`crate::executor::MorselPool::ranges`] divides the morsel budget by
/// this). 1 for empty tables so callers never divide by zero.
pub(crate) fn approx_row_bytes(t: &Table) -> usize {
    (t.byte_size() / t.num_rows().max(1)).max(1)
}

/// Gather rows by index with per-column parallelism: each column's gather
/// is one independent task (column results depend only on `(column,
/// indices)`, so scheduling cannot change the output).
pub(crate) fn gather_table(
    t: &Table,
    indices: &[u32],
    pool: &crate::executor::MorselPool,
) -> Table {
    if !pool.is_parallel() {
        return t.gather(indices);
    }
    let cols = t.columns();
    let gathered = pool.run(cols.len(), |ci| cols[ci].gather(indices));
    Table::new(t.schema().clone(), gathered).expect("gather preserves schema")
}

/// Row equality on key columns across two tables (SQL semantics for the
/// hash path: null == null so nulls group together; join kernels that need
/// `NULL != NULL` filter separately).
pub fn rows_equal(
    left: &Table,
    lrow: usize,
    lcols: &[usize],
    right: &Table,
    rrow: usize,
    rcols: &[usize],
) -> bool {
    debug_assert_eq!(lcols.len(), rcols.len());
    for (&lc, &rc) in lcols.iter().zip(rcols) {
        let a = &left.columns()[lc];
        let b = &right.columns()[rc];
        let av = a.is_valid(lrow);
        let bv = b.is_valid(rrow);
        if av != bv {
            return false;
        }
        if !av {
            continue; // both null
        }
        let eq = match (a, b) {
            (Column::Int64(x), Column::Int64(y)) => x.values[lrow] == y.values[rrow],
            (Column::Float64(x), Column::Float64(y)) => x.values[lrow] == y.values[rrow],
            (Column::Bool(x), Column::Bool(y)) => x.values[lrow] == y.values[rrow],
            (Column::Utf8(x), Column::Utf8(y)) => x.get(lrow) == y.get(rrow),
            _ => false,
        };
        if !eq {
            return false;
        }
    }
    true
}

/// Row ordering on key columns across two tables (nulls first).
pub fn rows_cmp(
    left: &Table,
    lrow: usize,
    lcols: &[usize],
    right: &Table,
    rrow: usize,
    rcols: &[usize],
) -> Ordering {
    for (&lc, &rc) in lcols.iter().zip(rcols) {
        let a = &left.columns()[lc];
        let b = &right.columns()[rc];
        let av = a.is_valid(lrow);
        let bv = b.is_valid(rrow);
        let ord = match (av, bv) {
            (false, false) => Ordering::Equal,
            (false, true) => Ordering::Less,
            (true, false) => Ordering::Greater,
            (true, true) => match (a, b) {
                (Column::Int64(x), Column::Int64(y)) => x.values[lrow].cmp(&y.values[rrow]),
                (Column::Float64(x), Column::Float64(y)) => x.values[lrow]
                    .partial_cmp(&y.values[rrow])
                    .unwrap_or(Ordering::Equal),
                (Column::Bool(x), Column::Bool(y)) => x.values[lrow].cmp(&y.values[rrow]),
                (Column::Utf8(x), Column::Utf8(y)) => x.get(lrow).cmp(y.get(rrow)),
                _ => Ordering::Equal,
            },
        };
        if ord != Ordering::Equal {
            return ord;
        }
    }
    Ordering::Equal
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> Table {
        Table::from_columns(vec![
            ("k", Column::from_opt_i64(&[Some(1), Some(2), None, Some(1)])),
            ("s", Column::from_strings(&["a", "b", "c", "a"])),
        ])
        .unwrap()
    }

    #[test]
    fn hashes_consistent_for_equal_rows() {
        let tab = t();
        let hs = row_hashes(&tab, &[0, 1], &NativeHasher).unwrap();
        assert_eq!(hs[0], hs[3]); // (1,"a") twice
        assert_ne!(hs[0], hs[1]);
    }

    #[test]
    fn null_rows_hash_to_sentinel_consistently() {
        let a = Table::from_columns(vec![("k", Column::from_opt_i64(&[None]))]).unwrap();
        let b = Table::from_columns(vec![("k", Column::from_opt_i64(&[None, Some(3)]))]).unwrap();
        let ha = row_hashes(&a, &[0], &NativeHasher).unwrap();
        let hb = row_hashes(&b, &[0], &NativeHasher).unwrap();
        assert_eq!(ha[0], hb[0]);
        assert_ne!(hb[0], hb[1]);
    }

    #[test]
    fn equality_and_order() {
        let tab = t();
        assert!(rows_equal(&tab, 0, &[0, 1], &tab, 3, &[0, 1]));
        assert!(!rows_equal(&tab, 0, &[0, 1], &tab, 1, &[0, 1]));
        // null == null under grouping semantics
        assert!(rows_equal(&tab, 2, &[0], &tab, 2, &[0]));
        assert_eq!(rows_cmp(&tab, 0, &[0], &tab, 1, &[0]), Ordering::Less);
        // nulls sort first
        assert_eq!(rows_cmp(&tab, 2, &[0], &tab, 0, &[0]), Ordering::Less);
    }

    #[test]
    fn float_hash_canonicalizes_zero() {
        let tab = Table::from_columns(vec![("f", Column::from_f64(vec![0.0, -0.0]))]).unwrap();
        let hs = row_hashes(&tab, &[0], &NativeHasher).unwrap();
        assert_eq!(hs[0], hs[1]);
    }
}

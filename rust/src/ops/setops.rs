//! SQL set operators over whole rows: union (all/distinct), intersect,
//! difference — part of the Cylon DDF operator surface (paper Fig 3's
//! operator families).

use super::distinct::distinct_with_hasher;
use super::kernels::{row_hashes, rows_equal, KeyHasher, NativeHasher};
use crate::error::Result;
use crate::table::Table;
use std::collections::HashMap;

fn all_cols(t: &Table) -> Vec<usize> {
    (0..t.num_columns()).collect()
}

/// Bag union: concatenation (schemas must be compatible).
pub fn union_all(a: &Table, b: &Table) -> Result<Table> {
    Table::concat(&[a, b])
}

/// Set union: concatenation then whole-row distinct.
pub fn union_distinct(a: &Table, b: &Table) -> Result<Table> {
    let u = union_all(a, b)?;
    let cols = all_cols(&u);
    distinct_with_hasher(&u, &cols, &NativeHasher)
}

/// Rows of `a` that (whole-row) appear in `b`, deduplicated.
pub fn intersect(a: &Table, b: &Table) -> Result<Table> {
    intersect_with_hasher(a, b, &NativeHasher)
}

/// [`intersect`] with an explicit hasher.
pub fn intersect_with_hasher(a: &Table, b: &Table, hasher: &dyn KeyHasher) -> Result<Table> {
    a.schema().check_compatible(b.schema())?;
    let acols = all_cols(a);
    let bcols = all_cols(b);
    let bh = row_hashes(b, &bcols, hasher)?;
    let mut bmap: HashMap<i64, Vec<u32>> = HashMap::new();
    for (i, &h) in bh.iter().enumerate() {
        bmap.entry(h).or_default().push(i as u32);
    }
    let da = distinct_with_hasher(a, &acols, hasher)?;
    let dh = row_hashes(&da, &acols, hasher)?;
    let mut keep = Vec::new();
    for (i, &h) in dh.iter().enumerate() {
        if let Some(cands) = bmap.get(&h) {
            if cands
                .iter()
                .any(|&j| rows_equal(&da, i, &acols, b, j as usize, &bcols))
            {
                keep.push(i as u32);
            }
        }
    }
    Ok(da.gather(&keep))
}

/// Rows of `a` that (whole-row) do NOT appear in `b`, deduplicated
/// (SQL `EXCEPT`).
pub fn difference(a: &Table, b: &Table) -> Result<Table> {
    difference_with_hasher(a, b, &NativeHasher)
}

/// [`difference`] with an explicit hasher.
pub fn difference_with_hasher(a: &Table, b: &Table, hasher: &dyn KeyHasher) -> Result<Table> {
    a.schema().check_compatible(b.schema())?;
    let acols = all_cols(a);
    let bcols = all_cols(b);
    let bh = row_hashes(b, &bcols, hasher)?;
    let mut bmap: HashMap<i64, Vec<u32>> = HashMap::new();
    for (i, &h) in bh.iter().enumerate() {
        bmap.entry(h).or_default().push(i as u32);
    }
    let da = distinct_with_hasher(a, &acols, hasher)?;
    let dh = row_hashes(&da, &acols, hasher)?;
    let mut keep = Vec::new();
    for (i, &h) in dh.iter().enumerate() {
        let hit = bmap.get(&h).map(|cands| {
            cands
                .iter()
                .any(|&j| rows_equal(&da, i, &acols, b, j as usize, &bcols))
        });
        if hit != Some(true) {
            keep.push(i as u32);
        }
    }
    Ok(da.gather(&keep))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;

    fn t(ks: Vec<i64>) -> Table {
        Table::from_columns(vec![("k", Column::from_i64(ks))]).unwrap()
    }

    fn keys(t: &Table) -> Vec<i64> {
        let mut v = t.column(0).unwrap().i64_values().unwrap().to_vec();
        v.sort_unstable();
        v
    }

    #[test]
    fn union_variants() {
        let a = t(vec![1, 2, 2]);
        let b = t(vec![2, 3]);
        assert_eq!(union_all(&a, &b).unwrap().num_rows(), 5);
        assert_eq!(keys(&union_distinct(&a, &b).unwrap()), vec![1, 2, 3]);
    }

    #[test]
    fn intersect_dedups() {
        let a = t(vec![1, 2, 2, 3]);
        let b = t(vec![2, 3, 4]);
        assert_eq!(keys(&intersect(&a, &b).unwrap()), vec![2, 3]);
    }

    #[test]
    fn difference_except_semantics() {
        let a = t(vec![1, 2, 2, 3]);
        let b = t(vec![2]);
        assert_eq!(keys(&difference(&a, &b).unwrap()), vec![1, 3]);
        // empty b: difference = distinct(a)
        assert_eq!(keys(&difference(&a, &t(vec![])).unwrap()), vec![1, 2, 3]);
    }

    #[test]
    fn multi_column_rows() {
        let a = Table::from_columns(vec![
            ("k", Column::from_i64(vec![1, 1])),
            ("s", Column::from_strings(&["x", "y"])),
        ])
        .unwrap();
        let b = Table::from_columns(vec![
            ("k", Column::from_i64(vec![1])),
            ("s", Column::from_strings(&["y"])),
        ])
        .unwrap();
        let i = intersect(&a, &b).unwrap();
        assert_eq!(i.num_rows(), 1);
        assert_eq!(i.value(0, 1).unwrap().as_str(), Some("y"));
        let d = difference(&a, &b).unwrap();
        assert_eq!(d.num_rows(), 1);
        assert_eq!(d.value(0, 1).unwrap().as_str(), Some("x"));
    }

    #[test]
    fn incompatible_schema_errors() {
        let a = t(vec![1]);
        let b = Table::from_columns(vec![("f", Column::from_f64(vec![1.0]))]).unwrap();
        assert!(intersect(&a, &b).is_err());
        assert!(difference(&a, &b).is_err());
    }
}

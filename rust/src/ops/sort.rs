//! Local multi-key sort.
//!
//! The distributed sort (paper Fig 8 third panel) is a sample sort: sample
//! → broadcast splitters → range partition ([`super::partition_by_range`]) →
//! all-to-all → this local sort per worker.

use super::kernels::{gather_table, rows_cmp};
use crate::column::Column;
use crate::error::{Error, Result};
use crate::executor::MorselPool;
use crate::table::Table;
use std::cmp::Ordering;

/// One sort key.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SortKey {
    /// Column index.
    pub col: usize,
    /// Ascending order when true.
    pub ascending: bool,
}

impl SortKey {
    /// Ascending key on `col`.
    pub fn asc(col: usize) -> Self {
        SortKey { col, ascending: true }
    }
    /// Descending key on `col`.
    pub fn desc(col: usize) -> Self {
        SortKey { col, ascending: false }
    }
}

/// Options for [`sort`].
#[derive(Debug, Clone)]
pub struct SortOptions {
    /// Sort keys, most-significant first.
    pub keys: Vec<SortKey>,
    /// Stable sort (preserve input order of ties).
    pub stable: bool,
}

impl SortOptions {
    /// Single ascending key.
    pub fn by(col: usize) -> Self {
        SortOptions { keys: vec![SortKey::asc(col)], stable: false }
    }
    /// Single descending key.
    pub fn by_desc(col: usize) -> Self {
        SortOptions { keys: vec![SortKey::desc(col)], stable: false }
    }
    /// Builder-style stability toggle.
    pub fn stable(mut self) -> Self {
        self.stable = true;
        self
    }
}

/// Sort a table. Nulls sort first under ascending order (pandas
/// `na_position='first'` analogue), last under descending.
pub fn sort(t: &Table, opts: &SortOptions) -> Result<Table> {
    sort_with_pool(t, opts, &MorselPool::disabled())
}

/// [`sort`] on a morsel pool: parallel run-sort + k-way merge
/// ([`sort_indices_with_pool`]) followed by a per-column parallel gather.
pub fn sort_with_pool(t: &Table, opts: &SortOptions, pool: &MorselPool) -> Result<Table> {
    if opts.keys.is_empty() {
        return Err(Error::invalid("sort: empty key list"));
    }
    for k in &opts.keys {
        t.column(k.col)?;
    }
    let indices = sort_indices_with_pool(t, opts, pool)?;
    Ok(gather_table(t, &indices, pool))
}

/// The sorting comparator with the row-index tie-break that makes the
/// sort permutation *unique*: no two indices ever compare Equal, so the
/// serial sort, every run-sort and the k-way merge all converge on the
/// one same permutation (equal keys end up in input order — i.e. the
/// non-stable path now yields the stable answer too).
fn cmp_with_tiebreak(t: &Table, opts: &SortOptions, a: u32, b: u32) -> Ordering {
    for k in &opts.keys {
        let ord = rows_cmp(t, a as usize, &[k.col], t, b as usize, &[k.col]);
        let ord = if k.ascending { ord } else { ord.reverse() };
        if ord != Ordering::Equal {
            return ord;
        }
    }
    a.cmp(&b)
}

/// The permutation that sorts `t` (exposed for merge/splitter logic).
pub fn sort_indices(t: &Table, opts: &SortOptions) -> Result<Vec<u32>> {
    sort_indices_with_pool(t, opts, &MorselPool::disabled())
}

/// [`sort_indices`] on a morsel pool. Parallel pools sort
/// `min(threads, n)` contiguous runs concurrently, then merge under the
/// same tie-broken total order; because that order is strict (no equal
/// elements), the merged permutation is the unique sorted one regardless
/// of run count — serial and parallel outputs are identical.
pub fn sort_indices_with_pool(
    t: &Table,
    opts: &SortOptions,
    pool: &MorselPool,
) -> Result<Vec<u32>> {
    let n = t.num_rows();
    // Fast path: single int64 ascending non-null key — the benchmark
    // shape. The (value, index) key realizes the tie-break for free.
    let fast = if opts.keys.len() == 1 && opts.keys[0].ascending {
        match t.column(opts.keys[0].col)? {
            Column::Int64(c) if c.validity.is_none() => Some(&c.values),
            _ => None,
        }
    } else {
        None
    };
    let sort_run = |range: (usize, usize)| -> Vec<u32> {
        let (start, len) = range;
        let mut idx: Vec<u32> = (start as u32..(start + len) as u32).collect();
        if let Some(vals) = fast {
            idx.sort_unstable_by_key(|&i| (vals[i as usize], i));
        } else {
            idx.sort_unstable_by(|&a, &b| cmp_with_tiebreak(t, opts, a, b));
        }
        idx
    };
    if !pool.is_parallel() || n < 2 {
        return Ok(sort_run((0, n)));
    }
    let ranges = MorselPool::even_ranges(n, pool.threads());
    let runs = pool.run(ranges.len(), |m| sort_run(ranges[m]));
    // K-way merge by linear scan over the (few, = thread count) run
    // heads. Strict total order ⇒ exactly one minimal head each step.
    let mut out = Vec::with_capacity(n);
    let mut heads = vec![0usize; runs.len()];
    for _ in 0..n {
        let mut best: Option<usize> = None;
        for (r, run) in runs.iter().enumerate() {
            if heads[r] >= run.len() {
                continue;
            }
            best = match best {
                None => Some(r),
                Some(b) => {
                    let cand = run[heads[r]];
                    let cur = runs[b][heads[b]];
                    let less = if let Some(vals) = fast {
                        (vals[cand as usize], cand) < (vals[cur as usize], cur)
                    } else {
                        cmp_with_tiebreak(t, opts, cand, cur) == Ordering::Less
                    };
                    Some(if less { r } else { b })
                }
            };
        }
        let b = best.expect("n elements across runs");
        out.push(runs[b][heads[b]]);
        heads[b] += 1;
    }
    Ok(out)
}

/// Check whether `t` is sorted under `opts` (test/verification helper).
pub fn is_sorted(t: &Table, opts: &SortOptions) -> bool {
    for r in 1..t.num_rows() {
        for k in &opts.keys {
            let ord = rows_cmp(t, r - 1, &[k.col], t, r, &[k.col]);
            let ord = if k.ascending { ord } else { ord.reverse() };
            match ord {
                Ordering::Less => break,
                Ordering::Greater => return false,
                Ordering::Equal => continue,
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Value;

    #[test]
    fn single_key_fast_path() {
        let t = Table::from_columns(vec![
            ("k", Column::from_i64(vec![3, 1, 2])),
            ("v", Column::from_strings(&["c", "a", "b"])),
        ])
        .unwrap();
        let s = sort(&t, &SortOptions::by(0)).unwrap();
        assert_eq!(s.column(0).unwrap().i64_values().unwrap(), &[1, 2, 3]);
        assert_eq!(s.value(0, 1).unwrap(), Value::Utf8("a".into()));
        assert!(is_sorted(&s, &SortOptions::by(0)));
    }

    #[test]
    fn descending() {
        let t = Table::from_columns(vec![("k", Column::from_i64(vec![3, 1, 2]))]).unwrap();
        let s = sort(&t, &SortOptions::by_desc(0)).unwrap();
        assert_eq!(s.column(0).unwrap().i64_values().unwrap(), &[3, 2, 1]);
        assert!(is_sorted(&s, &SortOptions::by_desc(0)));
        assert!(!is_sorted(&s, &SortOptions::by(0)));
    }

    #[test]
    fn nulls_sort_first_ascending() {
        let t =
            Table::from_columns(vec![("k", Column::from_opt_i64(&[Some(2), None, Some(1)]))])
                .unwrap();
        let s = sort(&t, &SortOptions::by(0)).unwrap();
        assert!(s.value(0, 0).unwrap().is_null());
        assert_eq!(s.value(1, 0).unwrap(), Value::Int64(1));
    }

    #[test]
    fn multi_key_with_direction() {
        let t = Table::from_columns(vec![
            ("a", Column::from_i64(vec![1, 1, 2, 2])),
            ("b", Column::from_i64(vec![5, 9, 5, 9])),
        ])
        .unwrap();
        let s = sort(
            &t,
            &SortOptions {
                keys: vec![SortKey::asc(0), SortKey::desc(1)],
                stable: false,
            },
        )
        .unwrap();
        assert_eq!(s.column(1).unwrap().i64_values().unwrap(), &[9, 5, 9, 5]);
    }

    #[test]
    fn stable_preserves_tie_order() {
        let t = Table::from_columns(vec![
            ("k", Column::from_i64(vec![1, 1, 1])),
            ("pos", Column::from_i64(vec![0, 1, 2])),
        ])
        .unwrap();
        let s = sort(&t, &SortOptions::by(0).stable()).unwrap();
        assert_eq!(s.column(1).unwrap().i64_values().unwrap(), &[0, 1, 2]);
    }

    #[test]
    fn string_sort() {
        let t = Table::from_columns(vec![("s", Column::from_strings(&["b", "a", "c"]))]).unwrap();
        let s = sort(&t, &SortOptions::by(0)).unwrap();
        assert_eq!(s.value(0, 0).unwrap(), Value::Utf8("a".into()));
        assert_eq!(s.value(2, 0).unwrap(), Value::Utf8("c".into()));
    }
}

//! Row sampling and splitter derivation — the basis of the distributed
//! sample sort and of the paper's (§VI) sample-based repartitioning plan.

use super::sort::{sort_indices, SortOptions};
use crate::error::Result;
use crate::table::Table;
use crate::util::SplitMix64;

/// Uniformly sample `k` rows (without replacement when `k ≤ n`).
pub fn sample_rows(t: &Table, k: usize, seed: u64) -> Table {
    let n = t.num_rows();
    if k >= n {
        return t.clone();
    }
    // Floyd's algorithm for a k-subset.
    let mut rng = SplitMix64::new(seed);
    let mut chosen: Vec<u32> = Vec::with_capacity(k);
    for j in (n - k)..n {
        let r = rng.next_bounded(j as u64 + 1) as u32;
        if chosen.contains(&r) {
            chosen.push(j as u32);
        } else {
            chosen.push(r);
        }
    }
    chosen.sort_unstable();
    t.gather(&chosen)
}

/// Derive `p - 1` splitter rows from a (gathered, global) sample so that
/// range-partitioning by them yields ~balanced partitions. Returns a table
/// holding only the key columns, sorted.
pub fn splitters_from_sample(
    sample: &Table,
    key_cols: &[usize],
    p: usize,
) -> Result<Table> {
    let opts = SortOptions {
        keys: key_cols.iter().map(|&c| super::sort::SortKey::asc(c)).collect(),
        stable: false,
    };
    let idx = sort_indices(sample, &opts)?;
    let sorted = sample.gather(&idx).project(key_cols)?;
    if p <= 1 || sorted.num_rows() == 0 {
        return Ok(sorted.slice(0, 0));
    }
    let n = sorted.num_rows();
    let mut picks: Vec<u32> = Vec::with_capacity(p - 1);
    for i in 1..p {
        let pos = (i * n / p).min(n - 1) as u32;
        picks.push(pos);
    }
    Ok(sorted.gather(&picks))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;

    #[test]
    fn sample_size_and_membership() {
        let t = Table::from_columns(vec![("k", Column::from_i64((0..1000).collect()))]).unwrap();
        let s = sample_rows(&t, 100, 7);
        assert_eq!(s.num_rows(), 100);
        let all: Vec<i64> = s.column(0).unwrap().i64_values().unwrap().to_vec();
        let mut dedup = all.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 100, "sampled with replacement");
        assert!(all.iter().all(|&k| (0..1000).contains(&k)));
    }

    #[test]
    fn sample_k_ge_n_is_identity() {
        let t = Table::from_columns(vec![("k", Column::from_i64(vec![1, 2]))]).unwrap();
        assert_eq!(sample_rows(&t, 10, 1), t);
    }

    #[test]
    fn splitters_are_sorted_and_sized() {
        let t = crate::datagen::uniform_table(11, 10_000, 0.9);
        let s = sample_rows(&t, 512, 3);
        let sp = splitters_from_sample(&s, &[0], 8).unwrap();
        assert_eq!(sp.num_rows(), 7);
        assert_eq!(sp.num_columns(), 1);
        let keys = sp.column(0).unwrap().i64_values().unwrap();
        for w in keys.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn splitters_balance_range_partition() {
        let t = crate::datagen::uniform_table(13, 20_000, 0.9);
        let s = sample_rows(&t, 2_000, 5);
        let sp = splitters_from_sample(&s, &[0], 4).unwrap();
        let parts = crate::ops::partition_by_range(&t, &[0], &sp, &[0]).unwrap();
        assert_eq!(parts.len(), 4);
        for p in &parts {
            let frac = p.num_rows() as f64 / 20_000.0;
            assert!((0.15..0.35).contains(&frac), "unbalanced: {frac}");
        }
    }
}

//! Distinct / duplicate elimination (pandas `drop_duplicates`).

use super::kernels::{row_hashes, rows_equal, KeyHasher, NativeHasher};
use crate::error::Result;
use crate::table::Table;
use std::collections::HashMap;

/// Keep the first occurrence of each distinct key-tuple (`key_cols`; pass
/// all columns for whole-row distinct).
pub fn distinct(t: &Table, key_cols: &[usize]) -> Result<Table> {
    distinct_with_hasher(t, key_cols, &NativeHasher)
}

/// [`distinct`] with an explicit hasher.
pub fn distinct_with_hasher(
    t: &Table,
    key_cols: &[usize],
    hasher: &dyn KeyHasher,
) -> Result<Table> {
    let n = t.num_rows();
    let mut keep: Vec<u32> = Vec::new();

    // fast path: single non-null int64 key
    if let [kc] = key_cols {
        if let crate::column::Column::Int64(c) = t.column(*kc)? {
            if c.validity.is_none() {
                let mut seen: crate::util::hash::FastMap<i64, ()> =
                    crate::util::hash::fast_map_with_capacity(n);
                for (i, &k) in c.values.iter().enumerate() {
                    if seen.insert(k, ()).is_none() {
                        keep.push(i as u32);
                    }
                }
                return Ok(t.gather(&keep));
            }
        }
    }

    let hashes = row_hashes(t, key_cols, hasher)?;
    let mut buckets: HashMap<i64, Vec<u32>> = HashMap::new();
    for i in 0..n {
        let bucket = buckets.entry(hashes[i]).or_default();
        let dup = bucket
            .iter()
            .any(|&j| rows_equal(t, j as usize, key_cols, t, i, key_cols));
        if !dup {
            bucket.push(i as u32);
            keep.push(i as u32);
        }
    }
    Ok(t.gather(&keep))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::types::Value;

    #[test]
    fn keeps_first_occurrence() {
        let t = Table::from_columns(vec![
            ("k", Column::from_i64(vec![1, 2, 1, 3, 2])),
            ("v", Column::from_i64(vec![10, 20, 30, 40, 50])),
        ])
        .unwrap();
        let d = distinct(&t, &[0]).unwrap();
        assert_eq!(d.column(0).unwrap().i64_values().unwrap(), &[1, 2, 3]);
        // first occurrence keeps its payload
        assert_eq!(d.value(0, 1).unwrap(), Value::Int64(10));
    }

    #[test]
    fn whole_row_distinct() {
        let t = Table::from_columns(vec![
            ("k", Column::from_i64(vec![1, 1, 1])),
            ("v", Column::from_i64(vec![10, 10, 20])),
        ])
        .unwrap();
        let d = distinct(&t, &[0, 1]).unwrap();
        assert_eq!(d.num_rows(), 2);
    }

    #[test]
    fn null_keys_are_one_group() {
        let t = Table::from_columns(vec![(
            "k",
            Column::from_opt_i64(&[None, Some(1), None]),
        )])
        .unwrap();
        let d = distinct(&t, &[0]).unwrap();
        assert_eq!(d.num_rows(), 2);
    }

    #[test]
    fn string_distinct() {
        let t =
            Table::from_columns(vec![("s", Column::from_strings(&["a", "b", "a"]))]).unwrap();
        let d = distinct(&t, &[0]).unwrap();
        assert_eq!(d.num_rows(), 2);
    }
}

//! Element-wise scalar arithmetic — the `add_scalar` stage of the paper's
//! Fig 9 pipeline (`join → groupby → sort → add_scalar`).
//!
//! Like key hashing, `add_scalar` has an AOT-compiled L2/L1 path
//! ([`crate::runtime::Kernels::add_scalar_f64`]) and this native fallback.

use crate::column::Column;
use crate::error::{Error, Result};
use crate::table::Table;

/// `t[col] += scalar` (int64 or float64 column; int columns take the
/// scalar truncated, wrapping on overflow — SQL-ish modular semantics).
/// Null slots stay null.
pub fn add_scalar(t: &Table, col: usize, scalar: f64) -> Result<Table> {
    map_numeric(t, col, |x| x + scalar, |x| x.wrapping_add(scalar as i64))
}

/// `t[col] *= scalar` (wrapping for int columns).
pub fn mul_scalar(t: &Table, col: usize, scalar: f64) -> Result<Table> {
    map_numeric(t, col, |x| x * scalar, |x| x.wrapping_mul(scalar as i64))
}

fn map_numeric(
    t: &Table,
    col: usize,
    f: impl Fn(f64) -> f64,
    g: impl Fn(i64) -> i64,
) -> Result<Table> {
    let c = t.column(col)?;
    let new_col = match c {
        Column::Float64(fc) => {
            let values = fc.values.iter().map(|&x| f(x)).collect();
            Column::Float64(crate::column::Float64Column::new(values, fc.validity.clone()))
        }
        Column::Int64(ic) => {
            let values = ic.values.iter().map(|&x| g(x)).collect();
            Column::Int64(crate::column::Int64Column::new(values, ic.validity.clone()))
        }
        other => {
            return Err(Error::Type(format!(
                "scalar arithmetic on non-numeric column {}",
                other.dtype()
            )))
        }
    };
    let mut cols: Vec<Column> = t.columns().to_vec();
    cols[col] = new_col;
    Table::new(t.schema().clone(), cols)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Value;

    #[test]
    fn add_int_and_float() {
        let t = Table::from_columns(vec![
            ("i", Column::from_i64(vec![1, 2])),
            ("f", Column::from_f64(vec![0.5, 1.5])),
        ])
        .unwrap();
        let a = add_scalar(&t, 0, 10.0).unwrap();
        assert_eq!(a.column(0).unwrap().i64_values().unwrap(), &[11, 12]);
        let b = add_scalar(&t, 1, 0.25).unwrap();
        assert_eq!(b.value(0, 1).unwrap(), Value::Float64(0.75));
    }

    #[test]
    fn nulls_preserved() {
        let t =
            Table::from_columns(vec![("i", Column::from_opt_i64(&[Some(1), None]))]).unwrap();
        let a = add_scalar(&t, 0, 1.0).unwrap();
        assert_eq!(a.value(0, 0).unwrap(), Value::Int64(2));
        assert!(a.value(1, 0).unwrap().is_null());
    }

    #[test]
    fn mul_and_type_error() {
        let t = Table::from_columns(vec![
            ("f", Column::from_f64(vec![2.0])),
            ("s", Column::from_strings(&["x"])),
        ])
        .unwrap();
        let m = mul_scalar(&t, 0, 3.0).unwrap();
        assert_eq!(m.value(0, 0).unwrap(), Value::Float64(6.0));
        assert!(add_scalar(&t, 1, 1.0).is_err());
    }
}

//! Local hash groupby with numeric aggregates.
//!
//! The distributed groupby (paper Fig 2 pattern) shuffles on key columns
//! then runs this kernel per worker; for algebraic aggregates `dist`
//! instead runs a *partial* local groupby, shuffles the much smaller
//! partials, and finalizes — the classic two-phase optimization.

use super::kernels::{row_hashes, rows_equal, KeyHasher, NativeHasher};
use crate::column::{Column, ColumnBuilder};
use crate::error::{Error, Result};
use crate::table::Table;
use crate::types::DType;
use std::collections::HashMap;

/// Aggregate functions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggFun {
    /// Sum of non-null values.
    Sum,
    /// Count of non-null values.
    Count,
    /// Min of non-null values.
    Min,
    /// Max of non-null values.
    Max,
    /// Arithmetic mean of non-null values.
    Mean,
    /// Sum of squares (building block of Var/Std; float64 output).
    SumSq,
    /// Population variance of non-null values.
    Var,
    /// Population standard deviation of non-null values.
    Std,
}

impl AggFun {
    /// Output column name prefix.
    pub fn label(&self) -> &'static str {
        match self {
            AggFun::Sum => "sum",
            AggFun::Count => "count",
            AggFun::Min => "min",
            AggFun::Max => "max",
            AggFun::Mean => "mean",
            AggFun::SumSq => "sumsq",
            AggFun::Var => "var",
            AggFun::Std => "std",
        }
    }
}

/// One aggregate: `fun(column)`.
#[derive(Debug, Clone, Copy)]
pub struct AggSpec {
    /// Value column index.
    pub col: usize,
    /// Aggregate function.
    pub fun: AggFun,
}

impl AggSpec {
    /// Convenience constructor.
    pub fn new(col: usize, fun: AggFun) -> Self {
        AggSpec { col, fun }
    }
}

/// Running accumulator for one (group, aggregate) cell.
#[derive(Debug, Clone, Copy)]
struct Acc {
    sum: f64,
    sumsq: f64,
    count: u64,
    min: f64,
    max: f64,
}

impl Acc {
    fn new() -> Self {
        Acc {
            sum: 0.0,
            sumsq: 0.0,
            count: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
    #[inline]
    fn update(&mut self, v: f64) {
        self.sum += v;
        self.sumsq += v * v;
        self.count += 1;
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
    }
    fn finish(&self, fun: AggFun) -> Option<f64> {
        if self.count == 0 && fun != AggFun::Count {
            return None;
        }
        Some(match fun {
            AggFun::Sum => self.sum,
            AggFun::Count => self.count as f64,
            AggFun::Min => self.min,
            AggFun::Max => self.max,
            AggFun::Mean => self.sum / self.count as f64,
            AggFun::SumSq => self.sumsq,
            AggFun::Var => {
                let mean = self.sum / self.count as f64;
                (self.sumsq / self.count as f64 - mean * mean).max(0.0)
            }
            AggFun::Std => {
                let mean = self.sum / self.count as f64;
                (self.sumsq / self.count as f64 - mean * mean).max(0.0).sqrt()
            }
        })
    }
}

/// Group `t` by `key_cols`, computing `aggs`. Output: key columns (first
/// occurrence order) followed by one float64/int64 column per aggregate
/// named `{fun}_{col_name}`.
pub fn groupby(t: &Table, key_cols: &[usize], aggs: &[AggSpec]) -> Result<Table> {
    groupby_with_hasher(t, key_cols, aggs, &NativeHasher)
}

/// [`groupby`] with an explicit key hasher.
pub fn groupby_with_hasher(
    t: &Table,
    key_cols: &[usize],
    aggs: &[AggSpec],
    hasher: &dyn KeyHasher,
) -> Result<Table> {
    if key_cols.is_empty() {
        return Err(Error::invalid("groupby: empty key column list"));
    }
    for a in aggs {
        let dt = t.schema().dtype(a.col)?;
        if !dt.is_numeric() {
            return Err(Error::Type(format!(
                "aggregate {} over non-numeric column {}",
                a.fun.label(),
                dt
            )));
        }
    }
    let n = t.num_rows();
    let mut group_of = vec![0u32; n];
    let mut reps: Vec<u32> = Vec::new();

    // Fast path: single non-null int64 key — direct value-keyed map, no
    // per-group bucket Vecs, no generic row comparisons (§Perf L3 iter 1:
    // this path took groupby from 0.2x to >1x vs the row-wise baseline).
    let fast = match (key_cols, t.column(key_cols[0])?) {
        ([_], crate::column::Column::Int64(c)) if c.validity.is_none() => Some(&c.values),
        _ => None,
    };
    if let Some(keys) = fast {
        let mut map: crate::util::hash::FastMap<i64, u32> =
            crate::util::hash::fast_map_with_capacity(n);
        for (i, &k) in keys.iter().enumerate() {
            let gid = *map.entry(k).or_insert_with(|| {
                reps.push(i as u32);
                (reps.len() - 1) as u32
            });
            group_of[i] = gid;
        }
    } else {
        // generic path: hash rows, chain per hash bucket, compare keys
        let hashes = row_hashes(t, key_cols, hasher)?;
        let mut head: HashMap<i64, Vec<u32>> = HashMap::new();
        for i in 0..n {
            let bucket = head.entry(hashes[i]).or_default();
            let mut gid = u32::MAX;
            for &cand in bucket.iter() {
                if rows_equal(t, reps[cand as usize] as usize, key_cols, t, i, key_cols) {
                    gid = cand;
                    break;
                }
            }
            if gid == u32::MAX {
                gid = reps.len() as u32;
                reps.push(i as u32);
                bucket.push(gid);
            }
            group_of[i] = gid;
        }
    }
    let ngroups = reps.len();

    // Accumulate per (group, agg).
    let mut accs = vec![Acc::new(); ngroups * aggs.len()];
    for (ai, a) in aggs.iter().enumerate() {
        let col = t.column(a.col)?;
        match col {
            Column::Int64(c) => {
                for i in 0..n {
                    if col.is_valid(i) {
                        accs[group_of[i] as usize * aggs.len() + ai].update(c.values[i] as f64);
                    }
                }
            }
            Column::Float64(c) => {
                for i in 0..n {
                    if col.is_valid(i) {
                        accs[group_of[i] as usize * aggs.len() + ai].update(c.values[i]);
                    }
                }
            }
            _ => unreachable!("validated numeric"),
        }
    }

    // Materialize: gather key columns at rep rows + build agg columns.
    let mut columns: Vec<Column> = Vec::with_capacity(key_cols.len() + aggs.len());
    let mut schema = crate::types::Schema::default();
    for &kc in key_cols {
        schema = schema.with_field(t.schema().field(kc)?.clone());
        columns.push(t.column(kc)?.gather(&reps));
    }
    for (ai, a) in aggs.iter().enumerate() {
        let src_name = &t.schema().field(a.col)?.name;
        let name = format!("{}_{}", a.fun.label(), src_name);
        let src_dtype = t.schema().dtype(a.col)?;
        // Sum/Min/Max over int64 stay int64; Count is int64; Mean is f64.
        let out_dtype = match (a.fun, src_dtype) {
            (AggFun::Count, _) => DType::Int64,
            (AggFun::Mean | AggFun::SumSq | AggFun::Var | AggFun::Std, _) => DType::Float64,
            (_, DType::Int64) => DType::Int64,
            _ => DType::Float64,
        };
        let mut b = ColumnBuilder::with_capacity(out_dtype, ngroups);
        for g in 0..ngroups {
            match accs[g * aggs.len() + ai].finish(a.fun) {
                None => b.push_null(),
                Some(v) => match out_dtype {
                    DType::Int64 => b.push_i64(v as i64),
                    DType::Float64 => b.push_f64(v),
                    _ => unreachable!(),
                },
            }
        }
        schema = schema.with_field(crate::types::Field::new(name, out_dtype));
        columns.push(b.finish());
    }
    Table::new(schema, columns)
}

/// Decompose an aggregate into its shuffle-able partial form:
/// `(partial aggs to compute locally, finalizer)`. Mean becomes
/// (Sum, Count) and is finalized as sum/count — used by the two-phase
/// distributed groupby.
pub fn partial_aggs(fun: AggFun) -> Vec<AggFun> {
    match fun {
        AggFun::Mean => vec![AggFun::Sum, AggFun::Count],
        AggFun::Var | AggFun::Std => vec![AggFun::Sum, AggFun::Count, AggFun::SumSq],
        AggFun::Count => vec![AggFun::Count],
        f => vec![f],
    }
}

/// Merge function for combining two partials of the same aggregate:
/// Sum/Count merge by Sum; Min by Min; Max by Max.
pub fn merge_fun(fun: AggFun) -> AggFun {
    match fun {
        AggFun::Sum | AggFun::Count | AggFun::SumSq => AggFun::Sum,
        AggFun::Min => AggFun::Min,
        AggFun::Max => AggFun::Max,
        AggFun::Mean | AggFun::Var | AggFun::Std => {
            unreachable!("decomposed before merge")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Value;

    fn t() -> Table {
        Table::from_columns(vec![
            ("k", Column::from_i64(vec![1, 2, 1, 2, 1])),
            ("v", Column::from_i64(vec![10, 20, 30, 40, 50])),
            ("w", Column::from_f64(vec![1.0, 2.0, 3.0, 4.0, 5.0])),
        ])
        .unwrap()
    }

    fn group_map(out: &Table, key_col: usize, val_col: usize) -> HashMap<i64, Value> {
        (0..out.num_rows())
            .map(|r| {
                (
                    out.value(r, key_col).unwrap().as_i64().unwrap(),
                    out.value(r, val_col).unwrap(),
                )
            })
            .collect()
    }

    #[test]
    fn sum_count_mean() {
        let out = groupby(
            &t(),
            &[0],
            &[
                AggSpec::new(1, AggFun::Sum),
                AggSpec::new(1, AggFun::Count),
                AggSpec::new(2, AggFun::Mean),
            ],
        )
        .unwrap();
        assert_eq!(out.num_rows(), 2);
        assert_eq!(out.schema().field(1).unwrap().name, "sum_v");
        let sums = group_map(&out, 0, 1);
        assert_eq!(sums[&1], Value::Int64(90));
        assert_eq!(sums[&2], Value::Int64(60));
        let counts = group_map(&out, 0, 2);
        assert_eq!(counts[&1], Value::Int64(3));
        let means = group_map(&out, 0, 3);
        assert_eq!(means[&1], Value::Float64(3.0));
    }

    #[test]
    fn min_max_keep_int_dtype() {
        let out = groupby(
            &t(),
            &[0],
            &[AggSpec::new(1, AggFun::Min), AggSpec::new(1, AggFun::Max)],
        )
        .unwrap();
        assert_eq!(out.schema().dtype(1).unwrap(), DType::Int64);
        let mins = group_map(&out, 0, 1);
        assert_eq!(mins[&1], Value::Int64(10));
        let maxs = group_map(&out, 0, 2);
        assert_eq!(maxs[&1], Value::Int64(50));
    }

    #[test]
    fn null_values_skipped_null_keys_group() {
        let tab = Table::from_columns(vec![
            ("k", Column::from_opt_i64(&[Some(1), None, None, Some(1)])),
            ("v", Column::from_opt_i64(&[Some(5), Some(7), None, None])),
        ])
        .unwrap();
        let out = groupby(
            &tab,
            &[0],
            &[AggSpec::new(1, AggFun::Sum), AggSpec::new(1, AggFun::Count)],
        )
        .unwrap();
        assert_eq!(out.num_rows(), 2); // groups: k=1, k=null
        for r in 0..2 {
            match out.value(r, 0).unwrap() {
                Value::Int64(1) => {
                    assert_eq!(out.value(r, 1).unwrap(), Value::Int64(5));
                    assert_eq!(out.value(r, 2).unwrap(), Value::Int64(1));
                }
                Value::Null => {
                    assert_eq!(out.value(r, 1).unwrap(), Value::Int64(7));
                    assert_eq!(out.value(r, 2).unwrap(), Value::Int64(1));
                }
                other => panic!("unexpected key {other:?}"),
            }
        }
    }

    #[test]
    fn multi_key_groups() {
        let tab = Table::from_columns(vec![
            ("a", Column::from_i64(vec![1, 1, 2, 1])),
            ("b", Column::from_strings(&["x", "y", "x", "x"])),
            ("v", Column::from_i64(vec![1, 1, 1, 1])),
        ])
        .unwrap();
        let out = groupby(&tab, &[0, 1], &[AggSpec::new(2, AggFun::Count)]).unwrap();
        assert_eq!(out.num_rows(), 3);
    }

    #[test]
    fn rejects_non_numeric_agg() {
        let tab = Table::from_columns(vec![
            ("k", Column::from_i64(vec![1])),
            ("s", Column::from_strings(&["x"])),
        ])
        .unwrap();
        assert!(groupby(&tab, &[0], &[AggSpec::new(1, AggFun::Sum)]).is_err());
    }

    #[test]
    fn empty_table_yields_empty() {
        let e = Table::empty(t().schema().clone());
        let out = groupby(&e, &[0], &[AggSpec::new(1, AggFun::Sum)]).unwrap();
        assert_eq!(out.num_rows(), 0);
        assert_eq!(out.num_columns(), 2);
    }
}

//! Local hash groupby with numeric aggregates.
//!
//! The distributed groupby (paper Fig 2 pattern) shuffles on key columns
//! then runs this kernel per worker; for algebraic aggregates `dist`
//! instead runs a *partial* local groupby, shuffles the much smaller
//! partials, and finalizes — the classic two-phase optimization.

use super::kernels::{
    approx_row_bytes, row_hashes_range, rows_equal, utf8_dict_encode, KeyHasher, NativeHasher,
};
use crate::column::{Column, ColumnBuilder};
use crate::error::{Error, Result};
use crate::executor::MorselPool;
use crate::table::Table;
use crate::types::DType;
use crate::util::hash::{fast_map_with_capacity, FastMap};

/// Aggregate functions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggFun {
    /// Sum of non-null values.
    Sum,
    /// Count of non-null values.
    Count,
    /// Min of non-null values.
    Min,
    /// Max of non-null values.
    Max,
    /// Arithmetic mean of non-null values.
    Mean,
    /// Sum of squares (building block of Var/Std; float64 output).
    SumSq,
    /// Population variance of non-null values.
    Var,
    /// Population standard deviation of non-null values.
    Std,
}

impl AggFun {
    /// Output column name prefix.
    pub fn label(&self) -> &'static str {
        match self {
            AggFun::Sum => "sum",
            AggFun::Count => "count",
            AggFun::Min => "min",
            AggFun::Max => "max",
            AggFun::Mean => "mean",
            AggFun::SumSq => "sumsq",
            AggFun::Var => "var",
            AggFun::Std => "std",
        }
    }
}

/// One aggregate: `fun(column)`.
#[derive(Debug, Clone, Copy)]
pub struct AggSpec {
    /// Value column index.
    pub col: usize,
    /// Aggregate function.
    pub fun: AggFun,
}

impl AggSpec {
    /// Convenience constructor.
    pub fn new(col: usize, fun: AggFun) -> Self {
        AggSpec { col, fun }
    }
}

/// Running accumulator for one (group, aggregate) cell.
#[derive(Debug, Clone, Copy)]
struct Acc {
    sum: f64,
    sumsq: f64,
    count: u64,
    min: f64,
    max: f64,
}

impl Acc {
    fn new() -> Self {
        Acc {
            sum: 0.0,
            sumsq: 0.0,
            count: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
    #[inline]
    fn update(&mut self, v: f64) {
        self.sum += v;
        self.sumsq += v * v;
        self.count += 1;
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
    }
    fn finish(&self, fun: AggFun) -> Option<f64> {
        if self.count == 0 && fun != AggFun::Count {
            return None;
        }
        Some(match fun {
            AggFun::Sum => self.sum,
            AggFun::Count => self.count as f64,
            AggFun::Min => self.min,
            AggFun::Max => self.max,
            AggFun::Mean => self.sum / self.count as f64,
            AggFun::SumSq => self.sumsq,
            AggFun::Var => {
                let mean = self.sum / self.count as f64;
                (self.sumsq / self.count as f64 - mean * mean).max(0.0)
            }
            AggFun::Std => {
                let mean = self.sum / self.count as f64;
                (self.sumsq / self.count as f64 - mean * mean).max(0.0).sqrt()
            }
        })
    }
}

/// Group `t` by `key_cols`, computing `aggs`. Output: key columns (first
/// occurrence order) followed by one float64/int64 column per aggregate
/// named `{fun}_{col_name}`.
pub fn groupby(t: &Table, key_cols: &[usize], aggs: &[AggSpec]) -> Result<Table> {
    groupby_with_hasher(t, key_cols, aggs, &NativeHasher)
}

/// [`groupby`] with an explicit key hasher.
pub fn groupby_with_hasher(
    t: &Table,
    key_cols: &[usize],
    aggs: &[AggSpec],
    hasher: &dyn KeyHasher,
) -> Result<Table> {
    groupby_with_pool(t, key_cols, aggs, hasher, &MorselPool::disabled())
}

/// Per-morsel local grouping result: the distinct keys seen in the morsel
/// (as first-occurrence global row ids, in first-occurrence order) plus
/// each morsel row's local group id.
struct LocalGroups {
    reps: Vec<u32>,
    gid_of: Vec<u32>,
}

/// [`groupby_with_hasher`] on a morsel pool — the deterministic two-phase
/// parallel aggregation (DESIGN.md §11):
///
/// 1. every morsel groups its rows locally in parallel (thread-local
///    dictionaries — the "partials");
/// 2. the local dictionaries merge serially **in morsel order**, which
///    reproduces the serial first-occurrence group numbering exactly;
/// 3. rows are stably scattered by group id, and workers accumulate
///    disjoint group ranges in parallel — each accumulator still sees its
///    rows in ascending row order, so even float sums are bitwise equal
///    to the serial pass.
///
/// With the serial pool every phase degenerates to the classic one-pass
/// hash groupby.
pub fn groupby_with_pool(
    t: &Table,
    key_cols: &[usize],
    aggs: &[AggSpec],
    hasher: &dyn KeyHasher,
    pool: &MorselPool,
) -> Result<Table> {
    if key_cols.is_empty() {
        return Err(Error::invalid("groupby: empty key column list"));
    }
    for a in aggs {
        let dt = t.schema().dtype(a.col)?;
        if !dt.is_numeric() {
            return Err(Error::Type(format!(
                "aggregate {} over non-numeric column {}",
                a.fun.label(),
                dt
            )));
        }
    }
    let n = t.num_rows();

    // ---- phase 1+2: group ids (first-occurrence order) + rep rows ----
    //
    // Unified i64 key representation so one grouping loop serves three
    // key shapes: single non-null int64 keys group on the value itself
    // (§Perf L3 iter 1), single string keys group on dictionary codes
    // (null → -1, its own group — the same "nulls group together"
    // semantics as the hash path), everything else groups on row hashes
    // with rows_equal resolving collisions.
    let dict_codes: Option<Vec<i64>> = match (key_cols, t.column(key_cols[0])?) {
        ([_], Column::Utf8(c)) => Some(utf8_dict_encode(c).1),
        _ => None,
    };
    let exact: Option<&[i64]> = match (key_cols, t.column(key_cols[0])?) {
        ([_], Column::Int64(c)) if c.validity.is_none() => Some(&c.values),
        _ => dict_codes.as_deref(),
    };
    let hashes: Option<Vec<i64>> = if exact.is_some() {
        None
    } else {
        let ranges = pool.ranges(n, approx_row_bytes(t));
        let chunks = pool.run(ranges.len(), |m| {
            let (start, len) = ranges[m];
            row_hashes_range(t, key_cols, hasher, start, len)
        });
        let mut h = Vec::with_capacity(n);
        for ch in chunks {
            h.extend(ch?);
        }
        Some(h)
    };

    // Local grouping over one row range (the whole table when serial).
    let group_range = |start: usize, len: usize| -> LocalGroups {
        let mut reps: Vec<u32> = Vec::new();
        let mut gid_of: Vec<u32> = Vec::with_capacity(len);
        if let Some(keys) = exact {
            let mut map: FastMap<i64, u32> = fast_map_with_capacity(len);
            for row in start..start + len {
                let gid = *map.entry(keys[row]).or_insert_with(|| {
                    reps.push(row as u32);
                    (reps.len() - 1) as u32
                });
                gid_of.push(gid);
            }
        } else {
            let hashes = hashes.as_ref().expect("generic path has hashes");
            let mut buckets: FastMap<i64, Vec<u32>> = FastMap::default();
            for row in start..start + len {
                let bucket = buckets.entry(hashes[row]).or_default();
                let mut gid = u32::MAX;
                for &cand in bucket.iter() {
                    if rows_equal(t, reps[cand as usize] as usize, key_cols, t, row, key_cols) {
                        gid = cand;
                        break;
                    }
                }
                if gid == u32::MAX {
                    gid = reps.len() as u32;
                    reps.push(row as u32);
                    bucket.push(gid);
                }
                gid_of.push(gid);
            }
        }
        LocalGroups { reps, gid_of }
    };

    let ranges = pool.ranges(n, approx_row_bytes(t));
    let locals = pool.run(ranges.len(), |m| {
        let (start, len) = ranges[m];
        group_range(start, len)
    });

    // Merge local dictionaries in morsel order. Iterating morsels
    // ascending and each morsel's reps in local first-occurrence order
    // visits every key first at its global first occurrence, so global
    // gids and reps equal the serial single-pass assignment.
    let (reps, group_of): (Vec<u32>, Vec<u32>) = if locals.len() == 1 {
        let l = locals.into_iter().next().expect("one morsel");
        (l.reps, l.gid_of)
    } else {
        let mut reps: Vec<u32> = Vec::new();
        let mut group_of: Vec<u32> = Vec::with_capacity(n);
        let mut exact_map: FastMap<i64, u32> = FastMap::default();
        let mut hash_map: FastMap<i64, Vec<u32>> = FastMap::default();
        for l in locals {
            let mut remap: Vec<u32> = Vec::with_capacity(l.reps.len());
            for &rep in &l.reps {
                let gid = if let Some(keys) = exact {
                    *exact_map.entry(keys[rep as usize]).or_insert_with(|| {
                        reps.push(rep);
                        (reps.len() - 1) as u32
                    })
                } else {
                    let hashes = hashes.as_ref().expect("generic path has hashes");
                    let bucket = hash_map.entry(hashes[rep as usize]).or_default();
                    let mut gid = u32::MAX;
                    for &cand in bucket.iter() {
                        if rows_equal(
                            t,
                            reps[cand as usize] as usize,
                            key_cols,
                            t,
                            rep as usize,
                            key_cols,
                        ) {
                            gid = cand;
                            break;
                        }
                    }
                    if gid == u32::MAX {
                        gid = reps.len() as u32;
                        reps.push(rep);
                        bucket.push(gid);
                    }
                    gid
                };
                remap.push(gid);
            }
            group_of.extend(l.gid_of.iter().map(|&lg| remap[lg as usize]));
        }
        (reps, group_of)
    };
    let ngroups = reps.len();

    // ---- phase 3: accumulate per (group, agg) ----
    let agg_cols: Vec<&Column> = {
        let mut v = Vec::with_capacity(aggs.len());
        for a in aggs {
            v.push(t.column(a.col)?);
        }
        v
    };
    let accs: Vec<Acc> = if pool.is_parallel() && ngroups > 1 {
        // Stable scatter rows by gid: rows of each group land contiguous
        // and ascending, so each group's accumulator sees the same value
        // sequence as the serial row-order pass.
        let mut counts = vec![0u32; ngroups];
        for &g in &group_of {
            counts[g as usize] += 1;
        }
        let mut offsets = vec![0u32; ngroups + 1];
        for g in 0..ngroups {
            offsets[g + 1] = offsets[g] + counts[g];
        }
        let mut order = vec![0u32; n];
        let mut cursor = offsets[..ngroups].to_vec();
        for (row, &g) in group_of.iter().enumerate() {
            order[cursor[g as usize] as usize] = row as u32;
            cursor[g as usize] += 1;
        }
        // Chunk groups so each task covers roughly equal row mass.
        let target = n.div_ceil(pool.threads()).max(1);
        let mut tasks: Vec<(usize, usize)> = Vec::new();
        let (mut g0, mut mass) = (0usize, 0usize);
        for g in 0..ngroups {
            mass += counts[g] as usize;
            if mass >= target || g + 1 == ngroups {
                tasks.push((g0, g + 1));
                g0 = g + 1;
                mass = 0;
            }
        }
        let chunks = pool.run(tasks.len(), |ti| {
            let (lo, hi) = tasks[ti];
            let mut local = vec![Acc::new(); (hi - lo) * aggs.len()];
            for (ai, _) in aggs.iter().enumerate() {
                let col = agg_cols[ai];
                for g in lo..hi {
                    let rows = &order[offsets[g] as usize..offsets[g + 1] as usize];
                    let acc = &mut local[(g - lo) * aggs.len() + ai];
                    match col {
                        Column::Int64(c) => {
                            for &row in rows {
                                if col.is_valid(row as usize) {
                                    acc.update(c.values[row as usize] as f64);
                                }
                            }
                        }
                        Column::Float64(c) => {
                            for &row in rows {
                                if col.is_valid(row as usize) {
                                    acc.update(c.values[row as usize]);
                                }
                            }
                        }
                        _ => unreachable!("validated numeric"),
                    }
                }
            }
            local
        });
        let mut accs = Vec::with_capacity(ngroups * aggs.len());
        for ch in chunks {
            accs.extend(ch);
        }
        accs
    } else {
        let mut accs = vec![Acc::new(); ngroups * aggs.len()];
        for (ai, _) in aggs.iter().enumerate() {
            let col = agg_cols[ai];
            match col {
                Column::Int64(c) => {
                    for i in 0..n {
                        if col.is_valid(i) {
                            accs[group_of[i] as usize * aggs.len() + ai].update(c.values[i] as f64);
                        }
                    }
                }
                Column::Float64(c) => {
                    for i in 0..n {
                        if col.is_valid(i) {
                            accs[group_of[i] as usize * aggs.len() + ai].update(c.values[i]);
                        }
                    }
                }
                _ => unreachable!("validated numeric"),
            }
        }
        accs
    };

    // ---- phase 4: materialize keys + per-agg output columns ----
    let mut schema = crate::types::Schema::default();
    let mut columns: Vec<Column> = Vec::with_capacity(key_cols.len() + aggs.len());
    for &kc in key_cols {
        schema = schema.with_field(t.schema().field(kc)?.clone());
        columns.push(t.column(kc)?.gather(&reps));
    }
    let mut out_dtypes = Vec::with_capacity(aggs.len());
    for a in aggs {
        let src_name = &t.schema().field(a.col)?.name;
        let name = format!("{}_{}", a.fun.label(), src_name);
        let src_dtype = t.schema().dtype(a.col)?;
        // Sum/Min/Max over int64 stay int64; Count is int64; Mean is f64.
        let out_dtype = match (a.fun, src_dtype) {
            (AggFun::Count, _) => DType::Int64,
            (AggFun::Mean | AggFun::SumSq | AggFun::Var | AggFun::Std, _) => DType::Float64,
            (_, DType::Int64) => DType::Int64,
            _ => DType::Float64,
        };
        out_dtypes.push(out_dtype);
        schema = schema.with_field(crate::types::Field::new(name, out_dtype));
    }
    // One output column per aggregate — independent builds, so they run
    // as parallel tasks without changing any cell.
    let agg_columns = pool.run(aggs.len(), |ai| {
        let out_dtype = out_dtypes[ai];
        let mut b = ColumnBuilder::with_capacity(out_dtype, ngroups);
        for g in 0..ngroups {
            match accs[g * aggs.len() + ai].finish(aggs[ai].fun) {
                None => b.push_null(),
                Some(v) => match out_dtype {
                    DType::Int64 => b.push_i64(v as i64),
                    DType::Float64 => b.push_f64(v),
                    _ => unreachable!(),
                },
            }
        }
        b.finish()
    });
    columns.extend(agg_columns);
    Table::new(schema, columns)
}

/// Decompose an aggregate into its shuffle-able partial form:
/// `(partial aggs to compute locally, finalizer)`. Mean becomes
/// (Sum, Count) and is finalized as sum/count — used by the two-phase
/// distributed groupby.
pub fn partial_aggs(fun: AggFun) -> Vec<AggFun> {
    match fun {
        AggFun::Mean => vec![AggFun::Sum, AggFun::Count],
        AggFun::Var | AggFun::Std => vec![AggFun::Sum, AggFun::Count, AggFun::SumSq],
        AggFun::Count => vec![AggFun::Count],
        f => vec![f],
    }
}

/// Merge function for combining two partials of the same aggregate:
/// Sum/Count merge by Sum; Min by Min; Max by Max.
pub fn merge_fun(fun: AggFun) -> AggFun {
    match fun {
        AggFun::Sum | AggFun::Count | AggFun::SumSq => AggFun::Sum,
        AggFun::Min => AggFun::Min,
        AggFun::Max => AggFun::Max,
        AggFun::Mean | AggFun::Var | AggFun::Std => {
            unreachable!("decomposed before merge")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Value;
    use std::collections::HashMap;

    fn t() -> Table {
        Table::from_columns(vec![
            ("k", Column::from_i64(vec![1, 2, 1, 2, 1])),
            ("v", Column::from_i64(vec![10, 20, 30, 40, 50])),
            ("w", Column::from_f64(vec![1.0, 2.0, 3.0, 4.0, 5.0])),
        ])
        .unwrap()
    }

    fn group_map(out: &Table, key_col: usize, val_col: usize) -> HashMap<i64, Value> {
        (0..out.num_rows())
            .map(|r| {
                (
                    out.value(r, key_col).unwrap().as_i64().unwrap(),
                    out.value(r, val_col).unwrap(),
                )
            })
            .collect()
    }

    #[test]
    fn sum_count_mean() {
        let out = groupby(
            &t(),
            &[0],
            &[
                AggSpec::new(1, AggFun::Sum),
                AggSpec::new(1, AggFun::Count),
                AggSpec::new(2, AggFun::Mean),
            ],
        )
        .unwrap();
        assert_eq!(out.num_rows(), 2);
        assert_eq!(out.schema().field(1).unwrap().name, "sum_v");
        let sums = group_map(&out, 0, 1);
        assert_eq!(sums[&1], Value::Int64(90));
        assert_eq!(sums[&2], Value::Int64(60));
        let counts = group_map(&out, 0, 2);
        assert_eq!(counts[&1], Value::Int64(3));
        let means = group_map(&out, 0, 3);
        assert_eq!(means[&1], Value::Float64(3.0));
    }

    #[test]
    fn min_max_keep_int_dtype() {
        let out = groupby(
            &t(),
            &[0],
            &[AggSpec::new(1, AggFun::Min), AggSpec::new(1, AggFun::Max)],
        )
        .unwrap();
        assert_eq!(out.schema().dtype(1).unwrap(), DType::Int64);
        let mins = group_map(&out, 0, 1);
        assert_eq!(mins[&1], Value::Int64(10));
        let maxs = group_map(&out, 0, 2);
        assert_eq!(maxs[&1], Value::Int64(50));
    }

    #[test]
    fn null_values_skipped_null_keys_group() {
        let tab = Table::from_columns(vec![
            ("k", Column::from_opt_i64(&[Some(1), None, None, Some(1)])),
            ("v", Column::from_opt_i64(&[Some(5), Some(7), None, None])),
        ])
        .unwrap();
        let out = groupby(
            &tab,
            &[0],
            &[AggSpec::new(1, AggFun::Sum), AggSpec::new(1, AggFun::Count)],
        )
        .unwrap();
        assert_eq!(out.num_rows(), 2); // groups: k=1, k=null
        for r in 0..2 {
            match out.value(r, 0).unwrap() {
                Value::Int64(1) => {
                    assert_eq!(out.value(r, 1).unwrap(), Value::Int64(5));
                    assert_eq!(out.value(r, 2).unwrap(), Value::Int64(1));
                }
                Value::Null => {
                    assert_eq!(out.value(r, 1).unwrap(), Value::Int64(7));
                    assert_eq!(out.value(r, 2).unwrap(), Value::Int64(1));
                }
                other => panic!("unexpected key {other:?}"),
            }
        }
    }

    #[test]
    fn multi_key_groups() {
        let tab = Table::from_columns(vec![
            ("a", Column::from_i64(vec![1, 1, 2, 1])),
            ("b", Column::from_strings(&["x", "y", "x", "x"])),
            ("v", Column::from_i64(vec![1, 1, 1, 1])),
        ])
        .unwrap();
        let out = groupby(&tab, &[0, 1], &[AggSpec::new(2, AggFun::Count)]).unwrap();
        assert_eq!(out.num_rows(), 3);
    }

    #[test]
    fn rejects_non_numeric_agg() {
        let tab = Table::from_columns(vec![
            ("k", Column::from_i64(vec![1])),
            ("s", Column::from_strings(&["x"])),
        ])
        .unwrap();
        assert!(groupby(&tab, &[0], &[AggSpec::new(1, AggFun::Sum)]).is_err());
    }

    #[test]
    fn empty_table_yields_empty() {
        let e = Table::empty(t().schema().clone());
        let out = groupby(&e, &[0], &[AggSpec::new(1, AggFun::Sum)]).unwrap();
        assert_eq!(out.num_rows(), 0);
        assert_eq!(out.num_columns(), 2);
    }
}

//! The 64-bit avalanche hash used by every key-based operator.
//!
//! This is the *same function* the L1 Pallas kernel
//! (`python/compile/kernels/hash64.py`) implements: a splitmix64-style
//! finalizer (Stafford variant 13). Keeping the constants identical on both
//! sides lets `cargo test` cross-check the PJRT-executed kernel against this
//! native implementation bit-for-bit, and lets the partitioner fall back to
//! the native path when artifacts are absent.

/// First multiply constant (Stafford mix13), as i64 two's-complement.
pub const HASH_M1: i64 = -49064778989728563i64; // 0xff51afd7ed558ccd
/// Second multiply constant (Stafford mix13), as i64 two's-complement.
pub const HASH_M2: i64 = -4265267296055464877i64; // 0xc4ceb9fe1a85ec53

/// splitmix64 finalizer over one key.
///
/// Full avalanche: every input bit affects every output bit, which is what
/// makes `hash64(k) % p` a uniform partitioner even for sequential keys.
#[inline(always)]
pub fn hash64(key: i64) -> i64 {
    let mut h = key as u64;
    h ^= h >> 33;
    h = h.wrapping_mul(HASH_M1 as u64);
    h ^= h >> 33;
    h = h.wrapping_mul(HASH_M2 as u64);
    h ^= h >> 33;
    h as i64
}

/// FNV-1a over a byte slice: the stable content fingerprint used for
/// plan-shaped checkpoint names ([`crate::plan::StageRecovery`]) and
/// byte-identity assertions in the elastic recovery tests. Not a key
/// hash — use [`hash64`] for partitioning.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Hash a slice of keys into `out` (native fallback for the PJRT kernel).
pub fn hash64_slice(keys: &[i64], out: &mut [i64]) {
    debug_assert_eq!(keys.len(), out.len());
    for (o, &k) in out.iter_mut().zip(keys) {
        *o = hash64(k);
    }
}

/// Partition id for a key given `num_partitions` (non-negative modulo).
#[inline(always)]
pub fn partition_of(key: i64, num_partitions: usize) -> usize {
    (hash64(key) as u64 % num_partitions as u64) as usize
}

/// `std::hash::Hasher` running splitmix64 — a fast integer hasher for the
/// operator hot paths (std's SipHash costs ~4x more per i64 key). Used via
/// [`FastMap`].
#[derive(Default, Clone)]
pub struct SplitMixHasher {
    state: u64,
}

impl std::hash::Hasher for SplitMixHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        // generic fallback (rare on hot paths): FNV-1a then one mix round
        let mut h = 0xcbf29ce484222325u64 ^ self.state;
        for &b in bytes {
            h = (h ^ b as u64).wrapping_mul(0x100000001b3);
        }
        self.state = hash64(h as i64) as u64;
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.state = hash64((v ^ self.state) as i64) as u64;
    }

    #[inline]
    fn write_i64(&mut self, v: i64) {
        self.write_u64(v as u64);
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.write_u64(v as u64);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.write_u64(v as u64);
    }
}

/// HashMap with the splitmix64 hasher — the map type of the operator hot
/// paths (groupby grouping, join build side).
pub type FastMap<K, V> =
    std::collections::HashMap<K, V, std::hash::BuildHasherDefault<SplitMixHasher>>;

/// [`FastMap`] with a row-count capacity hint.
pub fn fast_map_with_capacity<K, V>(cap: usize) -> FastMap<K, V> {
    FastMap::with_capacity_and_hasher(cap, Default::default())
}

/// Combine two hashes (for multi-key operators), boost-style.
#[inline(always)]
pub fn combine(a: i64, b: i64) -> i64 {
    let a = a as u64;
    let b = b as u64;
    (a ^ (b
        .wrapping_add(0x9e3779b97f4a7c15)
        .wrapping_add(a << 6)
        .wrapping_add(a >> 2))) as i64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn avalanche_nonzero() {
        // Flipping one input bit should flip ~half the output bits.
        let base = hash64(0x1234_5678_9abc_def0);
        for bit in 0..64 {
            let h = hash64(0x1234_5678_9abc_def0 ^ (1i64 << bit));
            let flipped = (base ^ h).count_ones();
            assert!(
                (16..=48).contains(&flipped),
                "bit {bit}: only {flipped} output bits flipped"
            );
        }
    }

    #[test]
    fn distinct_small_keys() {
        let hs: Vec<i64> = (0..1000).map(hash64).collect();
        let mut sorted = hs.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 1000, "collisions on tiny domain");
    }

    #[test]
    fn partition_uniformity() {
        let p = 8;
        let mut counts = vec![0usize; p];
        for k in 0..80_000i64 {
            counts[partition_of(k, p)] += 1;
        }
        for c in &counts {
            // each bucket within 5% of ideal 10_000
            assert!((9_500..=10_500).contains(c), "skewed bucket: {counts:?}");
        }
    }

    #[test]
    fn known_vector_matches_python_oracle() {
        // Mirrors python/tests/test_kernel.py::test_known_vectors — keep in sync.
        assert_eq!(hash64(0), 0);
        assert_eq!(hash64(1), -5451962507482445012);
        assert_eq!(hash64(42), -9148929187392628276);
        assert_eq!(hash64(-1), 7256831767414464289);
    }
}

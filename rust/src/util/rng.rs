//! Seeded PRNG (splitmix64 stream) — deterministic data generation without
//! external crates. Not cryptographic; used for workload generation and the
//! in-repo property-test harness.

/// splitmix64 stream generator.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// New generator from a seed. Distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        SplitMix64 {
            state: seed.wrapping_mul(0x9e3779b97f4a7c15) ^ 0x243f6a8885a308d3,
        }
    }

    /// Next raw u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// Next i64 (full range).
    #[inline]
    pub fn next_i64(&mut self) -> i64 {
        self.next_u64() as i64
    }

    /// Uniform in `[0, bound)`. `bound` must be > 0.
    #[inline]
    pub fn next_bounded(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform usize in `[lo, hi)` (hi > lo).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.next_bounded((hi - lo) as u64) as usize
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_bounded(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(7);
        let mut b = SplitMix64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn bounded_in_range() {
        let mut r = SplitMix64::new(1);
        for _ in 0..10_000 {
            assert!(r.next_bounded(17) < 17);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = SplitMix64::new(2);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02, "mean off: {sum}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut ys = xs.clone();
        ys.sort_unstable();
        assert_eq!(ys, (0..100).collect::<Vec<_>>());
    }
}

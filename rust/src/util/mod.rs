//! Small shared utilities: seeded PRNG, the splitmix64 hash (shared constant
//! with the L1 Pallas kernel), radix helpers, and timing.

pub mod hash;
pub mod rng;
pub mod time;

pub use hash::{fnv1a64, hash64, HASH_M1, HASH_M2};
pub use rng::SplitMix64;
pub use time::Stopwatch;

//! Minimal timing helper used by metrics and the bench harness.

use std::time::{Duration, Instant};

/// A restartable stopwatch accumulating elapsed time across segments.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    started: Option<Instant>,
    accum: Duration,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    /// New, stopped, zero-accumulated stopwatch.
    pub fn new() -> Self {
        Stopwatch { started: None, accum: Duration::ZERO }
    }

    /// Start (or restart) the current segment.
    pub fn start(&mut self) {
        self.started = Some(Instant::now());
    }

    /// Stop the current segment, folding it into the accumulator.
    pub fn stop(&mut self) {
        if let Some(t) = self.started.take() {
            self.accum += t.elapsed();
        }
    }

    /// Total accumulated time (running segment included).
    pub fn elapsed(&self) -> Duration {
        self.accum + self.started.map(|t| t.elapsed()).unwrap_or(Duration::ZERO)
    }

    /// Run `f`, adding its wall time to the accumulator, returning its value.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        self.start();
        let out = f();
        self.stop();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let mut sw = Stopwatch::new();
        sw.time(|| std::thread::sleep(Duration::from_millis(2)));
        let a = sw.elapsed();
        assert!(a >= Duration::from_millis(2));
        sw.time(|| std::thread::sleep(Duration::from_millis(2)));
        assert!(sw.elapsed() >= a + Duration::from_millis(2));
    }
}

//! Columns: homogeneously-typed, optionally-nullable vectors.
//!
//! Fixed-width columns store values in a plain `Vec`; strings use the Arrow
//! offsets+data layout. A missing validity bitmap means "all valid" (the
//! common fast path: kernels skip null checks entirely).

mod builder;
mod primitive;
mod string;

pub use builder::ColumnBuilder;
pub use primitive::{BoolColumn, Float64Column, Int64Column};
pub use string::StringColumn;

use crate::buffer::Bitmap;
use crate::error::{Error, Result};
use crate::types::{DType, Value};

/// A column of one of the supported domains.
#[derive(Debug, Clone, PartialEq)]
pub enum Column {
    /// int64 column.
    Int64(Int64Column),
    /// float64 column.
    Float64(Float64Column),
    /// utf8 column.
    Utf8(StringColumn),
    /// bool column.
    Bool(BoolColumn),
}

impl Column {
    /// Column from i64 values, all valid.
    pub fn from_i64(values: Vec<i64>) -> Column {
        Column::Int64(Int64Column::new(values, None))
    }

    /// Column from f64 values, all valid.
    pub fn from_f64(values: Vec<f64>) -> Column {
        Column::Float64(Float64Column::new(values, None))
    }

    /// Column from strings, all valid.
    pub fn from_strings<S: AsRef<str>>(values: &[S]) -> Column {
        Column::Utf8(StringColumn::from_strs(values))
    }

    /// Column from bools, all valid.
    pub fn from_bools(values: Vec<bool>) -> Column {
        Column::Bool(BoolColumn::new(values, None))
    }

    /// Column from optional i64s (None ⇒ null).
    pub fn from_opt_i64(values: &[Option<i64>]) -> Column {
        let mut b = ColumnBuilder::new(DType::Int64);
        for v in values {
            match v {
                Some(x) => b.push(Value::Int64(*x)).unwrap(),
                None => b.push_null(),
            }
        }
        b.finish()
    }

    /// Number of rows.
    pub fn len(&self) -> usize {
        match self {
            Column::Int64(c) => c.len(),
            Column::Float64(c) => c.len(),
            Column::Utf8(c) => c.len(),
            Column::Bool(c) => c.len(),
        }
    }

    /// True when the column has zero rows.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The column's domain.
    pub fn dtype(&self) -> DType {
        match self {
            Column::Int64(_) => DType::Int64,
            Column::Float64(_) => DType::Float64,
            Column::Utf8(_) => DType::Utf8,
            Column::Bool(_) => DType::Bool,
        }
    }

    /// Validity bitmap; `None` means all-valid.
    pub fn validity(&self) -> Option<&Bitmap> {
        match self {
            Column::Int64(c) => c.validity.as_ref(),
            Column::Float64(c) => c.validity.as_ref(),
            Column::Utf8(c) => c.validity.as_ref(),
            Column::Bool(c) => c.validity.as_ref(),
        }
    }

    /// Is row `i` valid?
    #[inline]
    pub fn is_valid(&self, i: usize) -> bool {
        self.validity().map(|b| b.get(i)).unwrap_or(true)
    }

    /// Number of null rows.
    pub fn null_count(&self) -> usize {
        self.validity().map(|b| b.count_null()).unwrap_or(0)
    }

    /// Dynamically-typed cell access (slow path, for display/tests).
    pub fn value(&self, i: usize) -> Value {
        if !self.is_valid(i) {
            return Value::Null;
        }
        match self {
            Column::Int64(c) => Value::Int64(c.values[i]),
            Column::Float64(c) => Value::Float64(c.values[i]),
            Column::Utf8(c) => Value::Utf8(c.get(i).to_string()),
            Column::Bool(c) => Value::Bool(c.values[i]),
        }
    }

    /// Gather rows by index: `out[j] = self[indices[j]]`.
    pub fn gather(&self, indices: &[u32]) -> Column {
        match self {
            Column::Int64(c) => Column::Int64(c.gather(indices)),
            Column::Float64(c) => Column::Float64(c.gather(indices)),
            Column::Utf8(c) => Column::Utf8(c.gather(indices)),
            Column::Bool(c) => Column::Bool(c.gather(indices)),
        }
    }

    /// Gather where index `u32::MAX` produces a null (outer-join fill).
    pub fn gather_opt(&self, indices: &[u32]) -> Column {
        match self {
            Column::Int64(c) => Column::Int64(c.gather_opt(indices)),
            Column::Float64(c) => Column::Float64(c.gather_opt(indices)),
            Column::Utf8(c) => Column::Utf8(c.gather_opt(indices)),
            Column::Bool(c) => Column::Bool(c.gather_opt(indices)),
        }
    }

    /// Concatenate columns of the same dtype.
    pub fn concat(cols: &[&Column]) -> Result<Column> {
        let first = cols
            .first()
            .ok_or_else(|| Error::invalid("concat of zero columns"))?;
        let dt = first.dtype();
        for c in cols {
            if c.dtype() != dt {
                return Err(Error::Type(format!(
                    "concat dtype mismatch: {} vs {}",
                    dt,
                    c.dtype()
                )));
            }
        }
        let mut b = ColumnBuilder::with_capacity(dt, cols.iter().map(|c| c.len()).sum());
        for c in cols {
            b.extend_from(c, 0, c.len());
        }
        Ok(b.finish())
    }

    /// Zero-copyish slice (`[offset, offset+len)`); strings re-pack data.
    pub fn slice(&self, offset: usize, len: usize) -> Column {
        let mut b = ColumnBuilder::with_capacity(self.dtype(), len);
        b.extend_from(self, offset, len);
        b.finish()
    }

    /// Borrow as i64 values (errors on other dtypes).
    pub fn i64_values(&self) -> Result<&[i64]> {
        match self {
            Column::Int64(c) => Ok(&c.values),
            other => Err(Error::Type(format!("expected int64, got {}", other.dtype()))),
        }
    }

    /// Borrow as f64 values (errors on other dtypes).
    pub fn f64_values(&self) -> Result<&[f64]> {
        match self {
            Column::Float64(c) => Ok(&c.values),
            other => Err(Error::Type(format!("expected float64, got {}", other.dtype()))),
        }
    }

    /// Approximate heap footprint in bytes (buffers only).
    pub fn byte_size(&self) -> usize {
        let vals = match self {
            Column::Int64(c) => c.values.len() * 8,
            Column::Float64(c) => c.values.len() * 8,
            Column::Utf8(c) => c.data.len() + (c.offsets.len()) * 4,
            Column::Bool(c) => c.values.len(),
        };
        vals + self.validity().map(|b| b.words().len() * 8).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_values() {
        let c = Column::from_i64(vec![1, 2, 3]);
        assert_eq!(c.len(), 3);
        assert_eq!(c.dtype(), DType::Int64);
        assert_eq!(c.value(1), Value::Int64(2));
        assert_eq!(c.null_count(), 0);
    }

    #[test]
    fn nullable_column() {
        let c = Column::from_opt_i64(&[Some(1), None, Some(3)]);
        assert_eq!(c.null_count(), 1);
        assert_eq!(c.value(1), Value::Null);
        assert_eq!(c.value(2), Value::Int64(3));
    }

    #[test]
    fn gather_and_concat() {
        let c = Column::from_i64(vec![10, 20, 30, 40]);
        let g = c.gather(&[3, 0, 0]);
        assert_eq!(g.i64_values().unwrap(), &[40, 10, 10]);
        let cc = Column::concat(&[&c, &g]).unwrap();
        assert_eq!(cc.len(), 7);
        assert_eq!(cc.value(4), Value::Int64(40));
    }

    #[test]
    fn gather_opt_nulls() {
        let c = Column::from_i64(vec![10, 20]);
        let g = c.gather_opt(&[1, u32::MAX, 0]);
        assert_eq!(g.value(0), Value::Int64(20));
        assert_eq!(g.value(1), Value::Null);
        assert_eq!(g.value(2), Value::Int64(10));
    }

    #[test]
    fn string_columns() {
        let c = Column::from_strings(&["ab", "", "xyz"]);
        assert_eq!(c.value(0), Value::Utf8("ab".into()));
        assert_eq!(c.value(1), Value::Utf8("".into()));
        let g = c.gather(&[2, 2]);
        assert_eq!(g.value(1), Value::Utf8("xyz".into()));
    }

    #[test]
    fn slice_mid() {
        let c = Column::from_i64((0..10).collect());
        let s = c.slice(3, 4);
        assert_eq!(s.i64_values().unwrap(), &[3, 4, 5, 6]);
    }

    #[test]
    fn concat_type_mismatch_errors() {
        let a = Column::from_i64(vec![1]);
        let b = Column::from_f64(vec![1.0]);
        assert!(Column::concat(&[&a, &b]).is_err());
    }
}

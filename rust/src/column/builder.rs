//! Incremental column construction.

use super::{BoolColumn, Column, Float64Column, Int64Column, StringColumn};
use crate::buffer::Bitmap;
use crate::error::{Error, Result};
use crate::types::{DType, Value};

/// Appends dynamically-typed values into a column of a fixed dtype.
#[derive(Debug)]
pub struct ColumnBuilder {
    dtype: DType,
    i64s: Vec<i64>,
    f64s: Vec<f64>,
    bools: Vec<bool>,
    str_offsets: Vec<i32>,
    str_data: Vec<u8>,
    validity: Bitmap,
    any_null: bool,
}

impl ColumnBuilder {
    /// New builder for `dtype`.
    pub fn new(dtype: DType) -> Self {
        Self::with_capacity(dtype, 0)
    }

    /// New builder with row-capacity hint.
    pub fn with_capacity(dtype: DType, cap: usize) -> Self {
        let mut b = ColumnBuilder {
            dtype,
            i64s: Vec::new(),
            f64s: Vec::new(),
            bools: Vec::new(),
            str_offsets: Vec::new(),
            str_data: Vec::new(),
            validity: Bitmap::new_null(0),
            any_null: false,
        };
        match dtype {
            DType::Int64 => b.i64s.reserve(cap),
            DType::Float64 => b.f64s.reserve(cap),
            DType::Bool => b.bools.reserve(cap),
            DType::Utf8 => {
                b.str_offsets.reserve(cap + 1);
                b.str_offsets.push(0);
            }
        }
        if dtype != DType::Utf8 {
            b.str_offsets.push(0);
        }
        b
    }

    /// Rows appended so far.
    pub fn len(&self) -> usize {
        self.validity.len()
    }

    /// True when no rows appended.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Append one value; must match the builder dtype (or be `Null`).
    pub fn push(&mut self, v: Value) -> Result<()> {
        match (&v, self.dtype) {
            (Value::Null, _) => {
                self.push_null();
                return Ok(());
            }
            (Value::Int64(x), DType::Int64) => self.push_i64(*x),
            (Value::Float64(x), DType::Float64) => self.push_f64(*x),
            (Value::Int64(x), DType::Float64) => self.push_f64(*x as f64),
            (Value::Utf8(s), DType::Utf8) => self.push_str(s),
            (Value::Bool(b), DType::Bool) => self.push_bool(*b),
            _ => {
                return Err(Error::Type(format!(
                    "cannot push {v:?} into {} column",
                    self.dtype
                )))
            }
        }
        Ok(())
    }

    /// Append a valid i64 (dtype must be Int64).
    pub fn push_i64(&mut self, x: i64) {
        debug_assert_eq!(self.dtype, DType::Int64);
        self.i64s.push(x);
        self.validity.push(true);
    }

    /// Append a valid f64 (dtype must be Float64).
    pub fn push_f64(&mut self, x: f64) {
        debug_assert_eq!(self.dtype, DType::Float64);
        self.f64s.push(x);
        self.validity.push(true);
    }

    /// Append a valid bool (dtype must be Bool).
    pub fn push_bool(&mut self, x: bool) {
        debug_assert_eq!(self.dtype, DType::Bool);
        self.bools.push(x);
        self.validity.push(true);
    }

    /// Append a valid string (dtype must be Utf8).
    pub fn push_str(&mut self, s: &str) {
        debug_assert_eq!(self.dtype, DType::Utf8);
        self.str_data.extend_from_slice(s.as_bytes());
        self.str_offsets.push(self.str_data.len() as i32);
        self.validity.push(true);
    }

    /// Append a null slot.
    pub fn push_null(&mut self) {
        self.any_null = true;
        match self.dtype {
            DType::Int64 => self.i64s.push(0),
            DType::Float64 => self.f64s.push(0.0),
            DType::Bool => self.bools.push(false),
            DType::Utf8 => self.str_offsets.push(self.str_data.len() as i32),
        }
        self.validity.push(false);
    }

    /// Bulk-append `len` rows of `col` starting at `offset` (same dtype).
    pub fn extend_from(&mut self, col: &Column, offset: usize, len: usize) {
        assert_eq!(col.dtype(), self.dtype, "extend_from dtype mismatch");
        match col {
            Column::Int64(c) => self.i64s.extend_from_slice(&c.values[offset..offset + len]),
            Column::Float64(c) => self.f64s.extend_from_slice(&c.values[offset..offset + len]),
            Column::Bool(c) => self.bools.extend_from_slice(&c.values[offset..offset + len]),
            Column::Utf8(c) => {
                let lo = c.offsets[offset] as usize;
                let hi = c.offsets[offset + len] as usize;
                let base = self.str_data.len() as i32 - c.offsets[offset];
                self.str_data.extend_from_slice(&c.data[lo..hi]);
                for i in offset + 1..=offset + len {
                    self.str_offsets.push(c.offsets[i] + base);
                }
            }
        }
        match col.validity() {
            Some(b) => {
                for i in offset..offset + len {
                    let v = b.get(i);
                    self.any_null |= !v;
                    self.validity.push(v);
                }
            }
            None => {
                for _ in 0..len {
                    self.validity.push(true);
                }
            }
        }
    }

    /// Finalize into a column.
    pub fn finish(self) -> Column {
        let validity = if self.any_null { Some(self.validity) } else { None };
        match self.dtype {
            DType::Int64 => Column::Int64(Int64Column::new(self.i64s, validity)),
            DType::Float64 => Column::Float64(Float64Column::new(self.f64s, validity)),
            DType::Bool => Column::Bool(BoolColumn::new(self.bools, validity)),
            DType::Utf8 => Column::Utf8(StringColumn::new(self.str_offsets, self.str_data, validity)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_with_nulls() {
        let mut b = ColumnBuilder::new(DType::Utf8);
        b.push_str("a");
        b.push_null();
        b.push_str("c");
        let c = b.finish();
        assert_eq!(c.len(), 3);
        assert_eq!(c.null_count(), 1);
        assert_eq!(c.value(2), Value::Utf8("c".into()));
    }

    #[test]
    fn type_checked_push() {
        let mut b = ColumnBuilder::new(DType::Int64);
        assert!(b.push(Value::Utf8("x".into())).is_err());
        assert!(b.push(Value::Int64(1)).is_ok());
        assert!(b.push(Value::Null).is_ok());
        assert_eq!(b.finish().len(), 2);
    }

    #[test]
    fn int_widens_to_float() {
        let mut b = ColumnBuilder::new(DType::Float64);
        b.push(Value::Int64(2)).unwrap();
        assert_eq!(b.finish().value(0), Value::Float64(2.0));
    }

    #[test]
    fn extend_from_strings_mid() {
        let src = Column::from_strings(&["aa", "bb", "cc", "dd"]);
        let mut b = ColumnBuilder::new(DType::Utf8);
        b.extend_from(&src, 1, 2);
        let c = b.finish();
        assert_eq!(c.value(0), Value::Utf8("bb".into()));
        assert_eq!(c.value(1), Value::Utf8("cc".into()));
    }
}

//! Fixed-width column storage (int64 / float64 / bool).

use crate::buffer::Bitmap;

macro_rules! primitive_column {
    ($name:ident, $ty:ty, $doc:expr) => {
        #[doc = $doc]
        #[derive(Debug, Clone, PartialEq)]
        pub struct $name {
            /// Value buffer (junk at null slots).
            pub values: Vec<$ty>,
            /// Validity; `None` ⇒ all valid.
            pub validity: Option<Bitmap>,
        }

        impl $name {
            /// New column; a provided all-valid bitmap is normalized away.
            pub fn new(values: Vec<$ty>, validity: Option<Bitmap>) -> Self {
                let validity = validity.filter(|b| !b.all_valid());
                if let Some(b) = &validity {
                    assert_eq!(b.len(), values.len(), "validity length mismatch");
                }
                $name { values, validity }
            }

            /// Row count.
            pub fn len(&self) -> usize {
                self.values.len()
            }

            /// True when empty.
            pub fn is_empty(&self) -> bool {
                self.values.is_empty()
            }

            /// Gather rows by u32 indices.
            pub fn gather(&self, indices: &[u32]) -> $name {
                let mut values = Vec::with_capacity(indices.len());
                for &i in indices {
                    values.push(self.values[i as usize]);
                }
                let validity = self.validity.as_ref().map(|b| b.gather(indices));
                $name::new(values, validity)
            }

            /// Gather with `u32::MAX` producing null slots.
            pub fn gather_opt(&self, indices: &[u32]) -> $name {
                let mut values = Vec::with_capacity(indices.len());
                let mut validity = Bitmap::new_null(indices.len());
                for (j, &i) in indices.iter().enumerate() {
                    if i == u32::MAX {
                        values.push(<$ty>::default());
                    } else {
                        values.push(self.values[i as usize]);
                        let valid =
                            self.validity.as_ref().map(|b| b.get(i as usize)).unwrap_or(true);
                        if valid {
                            validity.set(j, true);
                        }
                    }
                }
                $name::new(values, Some(validity))
            }
        }
    };
}

primitive_column!(Int64Column, i64, "int64 column buffer.");
primitive_column!(Float64Column, f64, "float64 column buffer.");
primitive_column!(BoolColumn, bool, "bool column buffer (byte per value).");

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_valid_bitmap_normalized() {
        let c = Int64Column::new(vec![1, 2], Some(Bitmap::new_valid(2)));
        assert!(c.validity.is_none());
    }

    #[test]
    fn gather_keeps_validity() {
        let c = Int64Column::new(vec![1, 2, 3], Some(Bitmap::from_bools(&[true, false, true])));
        let g = c.gather(&[1, 2]);
        assert!(!g.validity.as_ref().unwrap().get(0));
        assert!(g.validity.as_ref().unwrap().get(1));
    }

    #[test]
    fn gather_opt_sentinel() {
        let c = Float64Column::new(vec![1.5, 2.5], None);
        let g = c.gather_opt(&[u32::MAX, 1]);
        let v = g.validity.unwrap();
        assert!(!v.get(0));
        assert!(v.get(1));
        assert_eq!(g.values[1], 2.5);
    }
}

//! Variable-length UTF-8 column, Arrow offsets+data layout.

use crate::buffer::Bitmap;

/// UTF-8 column: `offsets.len() == len + 1`, string `i` is
/// `data[offsets[i]..offsets[i+1]]`.
#[derive(Debug, Clone, PartialEq)]
pub struct StringColumn {
    /// Monotone offsets into `data`, `len + 1` entries.
    pub offsets: Vec<i32>,
    /// Concatenated UTF-8 bytes.
    pub data: Vec<u8>,
    /// Validity; `None` ⇒ all valid.
    pub validity: Option<Bitmap>,
}

impl StringColumn {
    /// Build from raw parts (wire format path).
    pub fn new(offsets: Vec<i32>, data: Vec<u8>, validity: Option<Bitmap>) -> Self {
        assert!(!offsets.is_empty(), "offsets must have len+1 entries");
        let validity = validity.filter(|b| !b.all_valid());
        if let Some(b) = &validity {
            assert_eq!(b.len(), offsets.len() - 1);
        }
        StringColumn { offsets, data, validity }
    }

    /// Build from string slices, all valid.
    pub fn from_strs<S: AsRef<str>>(values: &[S]) -> Self {
        let mut offsets = Vec::with_capacity(values.len() + 1);
        let mut data = Vec::new();
        offsets.push(0);
        for v in values {
            data.extend_from_slice(v.as_ref().as_bytes());
            offsets.push(data.len() as i32);
        }
        StringColumn { offsets, data, validity: None }
    }

    /// Row count.
    pub fn len(&self) -> usize {
        self.offsets.len() - 1
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// String at row `i` (junk if the slot is null).
    #[inline]
    pub fn get(&self, i: usize) -> &str {
        let lo = self.offsets[i] as usize;
        let hi = self.offsets[i + 1] as usize;
        // Data is only ever built from &str, so it is valid UTF-8.
        std::str::from_utf8(&self.data[lo..hi]).expect("column holds valid utf8")
    }

    /// Gather rows by u32 indices.
    pub fn gather(&self, indices: &[u32]) -> StringColumn {
        let mut offsets = Vec::with_capacity(indices.len() + 1);
        let mut data = Vec::new();
        offsets.push(0i32);
        for &i in indices {
            let lo = self.offsets[i as usize] as usize;
            let hi = self.offsets[i as usize + 1] as usize;
            data.extend_from_slice(&self.data[lo..hi]);
            offsets.push(data.len() as i32);
        }
        let validity = self.validity.as_ref().map(|b| b.gather(indices));
        StringColumn::new(offsets, data, validity)
    }

    /// Gather with `u32::MAX` producing null slots.
    pub fn gather_opt(&self, indices: &[u32]) -> StringColumn {
        let mut offsets = Vec::with_capacity(indices.len() + 1);
        let mut data = Vec::new();
        let mut validity = Bitmap::new_null(indices.len());
        offsets.push(0i32);
        for (j, &i) in indices.iter().enumerate() {
            if i != u32::MAX {
                let lo = self.offsets[i as usize] as usize;
                let hi = self.offsets[i as usize + 1] as usize;
                data.extend_from_slice(&self.data[lo..hi]);
                let valid = self.validity.as_ref().map(|b| b.get(i as usize)).unwrap_or(true);
                if valid {
                    validity.set(j, true);
                }
            }
            offsets.push(data.len() as i32);
        }
        StringColumn::new(offsets, data, Some(validity))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout() {
        let c = StringColumn::from_strs(&["ab", "", "xyz"]);
        assert_eq!(c.len(), 3);
        assert_eq!(c.get(0), "ab");
        assert_eq!(c.get(1), "");
        assert_eq!(c.get(2), "xyz");
        assert_eq!(c.offsets, vec![0, 2, 2, 5]);
    }

    #[test]
    fn gather_repacks() {
        let c = StringColumn::from_strs(&["aa", "bb", "cc"]);
        let g = c.gather(&[2, 0]);
        assert_eq!(g.get(0), "cc");
        assert_eq!(g.get(1), "aa");
        assert_eq!(g.data.len(), 4);
    }

    #[test]
    fn gather_opt_null() {
        let c = StringColumn::from_strs(&["aa"]);
        let g = c.gather_opt(&[u32::MAX, 0]);
        assert!(!g.validity.as_ref().unwrap().get(0));
        assert_eq!(g.get(1), "aa");
    }
}

//! Distributed DDF operators — the paper's HP-DDF execution model
//! (§III-B): every distributed dataframe operator decomposes into a *core
//! local operator* ([`crate::ops`]) plus *auxiliary local operators*
//! (partitioners, samplers, materialization) plus *communication
//! operators* ([`crate::comm`] collectives), all executed inside a
//! [`CylonEnv`] on the stateful pseudo-BSP actor gang.
//!
//! Composition map (paper Fig 2):
//!
//! | operator | auxiliary | communication | core local |
//! |----------|-----------|---------------|------------|
//! | [`fn@join`] | hash partition both sides | shuffle ×2 | `ops::join` |
//! | [`fn@groupby`] (shuffle-first) | hash partition | shuffle | `ops::groupby` |
//! | [`fn@groupby`] (two-phase) | — | shuffle of *partials* | `ops::groupby` ×2 + finalize |
//! | [`fn@sort`] | sample, splitters, range partition | allgather + shuffle | `ops::sort` |
//! | [`distinct`]/set ops | hash partition (whole row) | shuffle | `ops::distinct`/`ops::setops` |
//! | [`fn@describe`] | stats encode/merge | allgather | `ops::describe` |
//! | [`rebalance`] | contiguous slicing | allreduce + shuffle | — |
//! | [`fn@pipeline`] | all of the above | all of the above | chained |
//!
//! Every operator records its phases through the [`CylonEnv`] timers
//! (compute / auxiliary locally, communication inside
//! [`crate::comm::CommContext`]) so the driver-side
//! [`crate::metrics::Breakdown`] reproduces the paper's Fig 6
//! comm-vs-compute experiment without extra instrumentation.
//!
//! Correctness rests on one invariant (property-tested in
//! `tests/proptest_invariants.rs`): the key hasher is identical on every
//! worker, so `hash(key) mod p` routes equal keys — from any table, on
//! any rank — to the same partition.
//!
//! These operators are *eager*: each call pays for its own exchange.
//! The lazy layer ([`crate::plan::DistFrame`]) builds a logical plan
//! over them and elides exchanges from partitioning lineage; its
//! lowering targets the `*_prepartitioned` / [`join_with_exchange`]
//! entry points exposed here.
//!
//! All exchanges here run **out-of-core**: [`shuffle_by_key`], the sort
//! exchange and `describe`'s allgather use the streaming collectives
//! ([`crate::comm::CommContext::shuffle_streamed`]), which move bounded
//! wire frames, spill past-budget receives to temp files via
//! [`crate::store::SpillBuffer`], and merge chunk-at-a-time — so a
//! join/groupby/sort whose shuffle would transiently exceed RAM
//! completes (each rank still holds its own output partition), with
//! spilled bytes reported in [`crate::metrics::SpillStats`].
//!
//! Exchanges can additionally run **skew-aware** ([`skew`], DESIGN.md
//! §8, opt-in via [`crate::config::SkewConfig`]): hot keys detected from
//! an oversampled allgather are split across a contiguous rank range —
//! [`join_skew`] / [`sort_balanced`] / [`shuffle_by_key_balanced`] and
//! the shuffle-first [`fn@groupby`] route through the split-assignment
//! plan, reporting what moved in [`crate::metrics::SkewStats`]. The
//! strict entry points below keep their co-location contracts unchanged.

pub mod describe;
pub mod groupby;
pub mod join;
pub mod pipeline;
pub mod setops;
pub mod skew;
pub mod sort;

pub use describe::describe;
pub use groupby::{groupby, groupby_prepartitioned, GroupbyStrategy};
pub use join::{join, join_prepartitioned, join_with_exchange, ExchangeSides};
pub use pipeline::{pipeline, PipelineReport, StageTiming};
pub use setops::{difference, distinct, distinct_prepartitioned, intersect, union_distinct};
pub use skew::{join_skew, shuffle_by_key_balanced, sort_balanced, SkewPlan};
pub use sort::{sort, sort_prepartitioned};

// Re-exports so call sites (and the prelude) can name option types from
// `dist` without importing `ops`.
pub use crate::ops::{AggFun, AggSpec, JoinOptions, SortOptions};

use crate::error::{Error, Result};
use crate::executor::CylonEnv;
use crate::metrics::Phase;
use crate::ops;
use crate::table::Table;

/// Hash-repartition `t` on `key_cols` across the gang: every row moves to
/// rank `hash(keys) mod world_size`. The partitioning step is an
/// *auxiliary* local operator; the all-to-all is a *communication*
/// operator. At parallelism 1 this is the identity.
///
/// This is the shared shuffle primitive under [`fn@join`], [`fn@groupby`] and
/// the set operators. It runs the **streaming** exchange
/// ([`crate::comm::CommContext::shuffle_streamed`]): payloads move as
/// bounded wire frames and received frames beyond the configured memory
/// budget ([`crate::config::ExchangeConfig`]) spill to temp files, so a
/// shuffle whose transient buffers would exceed RAM completes — with
/// results identical to the materializing path.
pub fn shuffle_by_key(t: &Table, key_cols: &[usize], env: &CylonEnv) -> Result<Table> {
    let p = env.world_size();
    if p == 1 {
        return Ok(t.clone());
    }
    let parts = env.time(Phase::Auxiliary, || {
        ops::partition_by_hash_with_pool(t, key_cols, p, env.hasher(), env.pool())
    })?;
    env.comm().shuffle_streamed(parts)
}

/// Outcome of a [`rebalance`]: what this rank held and shipped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RebalanceReport {
    /// Rows this rank held before rebalancing.
    pub rows_before: usize,
    /// Rows this rank shipped to other ranks.
    pub rows_sent: usize,
    /// Rows this rank received from other ranks.
    pub rows_received: usize,
}

/// Re-distribute rows so every rank holds an equal share (±1 row) while
/// preserving the global row order — the paper's (§VI) sample-free
/// repartitioning plan for skew recovery. Returns the balanced partition
/// and a per-rank [`RebalanceReport`].
pub fn rebalance(t: &Table, env: &CylonEnv) -> Result<(Table, RebalanceReport)> {
    let p = env.world_size();
    let n = t.num_rows();
    if p == 1 {
        return Ok((
            t.clone(),
            RebalanceReport { rows_before: n, rows_sent: 0, rows_received: 0 },
        ));
    }
    // Global row-count vector (one allreduce; each rank contributes its
    // count at its own slot).
    let mut counts = vec![0i64; p];
    counts[env.rank()] = n as i64;
    let counts = env.comm().allreduce_sum(&counts)?;
    let total: i64 = counts.iter().sum();

    // Target layout: rank j owns global rows [tstart[j], tstart[j+1]).
    let base = total / p as i64;
    let extra = (total % p as i64) as usize;
    let mut tstart = vec![0i64; p + 1];
    for j in 0..p {
        tstart[j + 1] = tstart[j] + base + i64::from(j < extra);
    }
    // My rows occupy global indices [my_start, my_start + n); intersect
    // with each target range — contiguous slices, no gather needed.
    let my_start: i64 = counts[..env.rank()].iter().sum();
    let parts = env.time(Phase::Auxiliary, || {
        (0..p)
            .map(|j| {
                let lo = (tstart[j] - my_start).clamp(0, n as i64) as usize;
                let hi = (tstart[j + 1] - my_start).clamp(0, n as i64) as usize;
                t.slice(lo, hi - lo)
            })
            .collect::<Vec<_>>()
    });
    let kept = parts[env.rank()].num_rows();
    let balanced = env.comm().shuffle_streamed(parts)?;
    let report = RebalanceReport {
        rows_before: n,
        rows_sent: n - kept,
        rows_received: balanced.num_rows() - kept,
    };
    Ok((balanced, report))
}

/// Shared argument check for key-driven operators.
pub(crate) fn check_keys(t: &Table, key_cols: &[usize], what: &str) -> Result<()> {
    if key_cols.is_empty() {
        return Err(Error::invalid(format!("{what}: empty key column list")));
    }
    for &c in key_cols {
        t.column(c)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::executor::{Cluster, CylonExecutor};

    #[test]
    fn shuffle_by_key_conserves_and_copartitions() {
        let p = 3;
        let c = Cluster::local(p).unwrap();
        let exec = CylonExecutor::new(&c, p).unwrap();
        let out = exec
            .run(|env| {
                let t = crate::datagen::partition_for_rank(5, 3000, 0.3, env.rank(), env.world_size());
                let s = shuffle_by_key(&t, &[0], env)?;
                Ok((t.num_rows(), s))
            })
            .unwrap()
            .wait()
            .unwrap();
        let before: usize = out.iter().map(|(n, _)| n).sum();
        let after: usize = out.iter().map(|(_, s)| s.num_rows()).sum();
        assert_eq!(before, after, "shuffle must conserve rows");
        // co-partitioning: no key appears on two ranks
        let mut owner = std::collections::BTreeMap::new();
        for (rank, (_, s)) in out.iter().enumerate() {
            for &k in s.column(0).unwrap().i64_values().unwrap() {
                let prev = owner.insert(k, rank);
                if let Some(prev) = prev {
                    assert_eq!(prev, rank, "key {k} split across ranks");
                }
            }
        }
    }

    #[test]
    fn rebalance_identity_at_p1() {
        let c = Cluster::local(1).unwrap();
        let exec = CylonExecutor::new(&c, 1).unwrap();
        let out = exec
            .run(|env| {
                let t = crate::datagen::uniform_table(1, 100, 0.9);
                let (b, rep) = rebalance(&t, env)?;
                Ok((b.num_rows(), rep))
            })
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(out[0].0, 100);
        assert_eq!(out[0].1.rows_sent, 0);
    }

    #[test]
    fn rebalance_preserves_global_order() {
        let p = 3;
        let c = Cluster::local(p).unwrap();
        let exec = CylonExecutor::new(&c, p).unwrap();
        let out = exec
            .run(|env| {
                // rank r holds rows [r*100, r*100 + 10*(r+1)): ragged but ordered
                let rows = 10 * (env.rank() + 1);
                let start = env.rank() as i64 * 100;
                let keys: Vec<i64> = (start..start + rows as i64).collect();
                let t = Table::from_columns(vec![(
                    "k",
                    crate::column::Column::from_i64(keys),
                )])?;
                let (b, _) = rebalance(&t, env)?;
                Ok(b)
            })
            .unwrap()
            .wait()
            .unwrap();
        let sizes: Vec<usize> = out.iter().map(|t| t.num_rows()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 60);
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
        // concatenated in rank order, keys stay globally ascending
        let mut last = i64::MIN;
        for t in &out {
            for &k in t.column(0).unwrap().i64_values().unwrap() {
                assert!(k > last, "order broken");
                last = k;
            }
        }
    }
}

//! Distributed whole-row set operators (paper Fig 3 operator families):
//! distinct, union, intersect, difference.
//!
//! All of them reduce to one invariant: hash-shuffling on *every* column
//! co-locates identical rows, after which the local kernels are exact.
//! A local pre-distinct runs before each shuffle to shrink the payload
//! (the same partial-then-exchange idea as the two-phase groupby).

use super::shuffle_by_key;
use crate::error::{Error, Result};
use crate::executor::CylonEnv;
use crate::metrics::Phase;
use crate::ops::{self, distinct::distinct_with_hasher, setops};
use crate::table::Table;

fn all_cols(t: &Table) -> Result<Vec<usize>> {
    if t.num_columns() == 0 {
        return Err(Error::invalid("set operator on zero-column table"));
    }
    Ok((0..t.num_columns()).collect())
}

/// Local whole-row distinct, then shuffle the survivors by whole-row hash
/// and dedupe again (duplicates from different ranks meet on one rank).
fn distinct_exchange(t: &Table, env: &CylonEnv) -> Result<Table> {
    let cols = all_cols(t)?;
    let local = env.time(Phase::Compute, || {
        distinct_with_hasher(t, &cols, env.hasher())
    })?;
    let shuffled = shuffle_by_key(&local, &cols, env)?;
    env.time(Phase::Compute, || {
        distinct_with_hasher(&shuffled, &cols, env.hasher())
    })
}

/// Distributed whole-row distinct.
pub fn distinct(t: &Table, env: &CylonEnv) -> Result<Table> {
    distinct_exchange(t, env)
}

/// Distinct that elides the shuffle: a single local dedupe, correct when
/// identical rows are already co-located — which *any* keyed partitioning
/// guarantees (rows equal on every column are equal on the partition
/// keys), e.g. the output of a distributed join, groupby or sort.
pub fn distinct_prepartitioned(t: &Table, env: &CylonEnv) -> Result<Table> {
    let cols = all_cols(t)?;
    env.time(Phase::Compute, || {
        distinct_with_hasher(t, &cols, env.hasher())
    })
}

/// Distributed set union: every distinct row of `a ∪ b` exactly once.
pub fn union_distinct(a: &Table, b: &Table, env: &CylonEnv) -> Result<Table> {
    let u = env.time(Phase::Auxiliary, || ops::union_all(a, b))?;
    distinct_exchange(&u, env)
}

/// Distributed intersect: distinct rows of `a` that also appear in `b`.
pub fn intersect(a: &Table, b: &Table, env: &CylonEnv) -> Result<Table> {
    a.schema().check_compatible(b.schema())?;
    let (sa, sb) = co_shuffle(a, b, env)?;
    env.time(Phase::Compute, || {
        setops::intersect_with_hasher(&sa, &sb, env.hasher())
    })
}

/// Distributed difference (SQL `EXCEPT`): distinct rows of `a` absent
/// from `b`.
pub fn difference(a: &Table, b: &Table, env: &CylonEnv) -> Result<Table> {
    a.schema().check_compatible(b.schema())?;
    let (sa, sb) = co_shuffle(a, b, env)?;
    env.time(Phase::Compute, || {
        setops::difference_with_hasher(&sa, &sb, env.hasher())
    })
}

/// Pre-distinct both sides locally, then co-shuffle by whole-row hash so
/// identical rows of `a` and `b` land on the same rank.
fn co_shuffle(a: &Table, b: &Table, env: &CylonEnv) -> Result<(Table, Table)> {
    let cols = all_cols(a)?;
    let la = env.time(Phase::Compute, || {
        distinct_with_hasher(a, &cols, env.hasher())
    })?;
    let lb = env.time(Phase::Compute, || {
        distinct_with_hasher(b, &cols, env.hasher())
    })?;
    let sa = shuffle_by_key(&la, &cols, env)?;
    let sb = shuffle_by_key(&lb, &cols, env)?;
    Ok((sa, sb))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen;
    use crate::executor::{Cluster, CylonExecutor};

    fn whole(seed: u64, rows: usize, p: usize) -> Table {
        let parts: Vec<Table> = (0..p)
            .map(|r| {
                datagen::partition_for_rank(seed, rows, 0.05, r, p)
                    .project(&[0])
                    .unwrap()
            })
            .collect();
        Table::concat_owned(parts).unwrap()
    }

    #[test]
    fn prepartitioned_distinct_after_groupby_matches_exchange() {
        // groupby hash-partitions on its keys; identical whole rows agree
        // on the keys, so they are co-located and one local dedupe is exact.
        let p = 3;
        let c = Cluster::local(p).unwrap();
        let exec = CylonExecutor::new(&c, p).unwrap();
        let out = exec
            .run(|env| {
                let t = datagen::partition_for_rank(605, 1500, 0.05, env.rank(), env.world_size());
                let g = super::super::groupby(
                    &t,
                    &[0],
                    &[crate::ops::AggSpec::new(1, crate::ops::AggFun::Count)],
                    super::super::GroupbyStrategy::TwoPhase,
                    env,
                )?;
                let fast = distinct_prepartitioned(&g, env)?;
                let slow = distinct(&g, env)?;
                Ok((fast.num_rows(), slow.num_rows()))
            })
            .unwrap()
            .wait()
            .unwrap();
        let fast: usize = out.iter().map(|(a, _)| a).sum();
        let slow: usize = out.iter().map(|(_, b)| b).sum();
        assert_eq!(fast, slow);
    }

    #[test]
    fn distinct_and_setops_match_local() {
        let p = 3;
        let c = Cluster::local(p).unwrap();
        let exec = CylonExecutor::new(&c, p).unwrap();
        let out = exec
            .run(|env| {
                let a = datagen::partition_for_rank(601, 1500, 0.05, env.rank(), env.world_size())
                    .project(&[0])?;
                let b = datagen::partition_for_rank(602, 1500, 0.05, env.rank(), env.world_size())
                    .project(&[0])?;
                let d = distinct(&a, env)?;
                let i = intersect(&a, &b, env)?;
                let x = difference(&a, &b, env)?;
                let u = union_distinct(&a, &b, env)?;
                Ok((d, i, x, u))
            })
            .unwrap()
            .wait()
            .unwrap();
        let (a, b) = (whole(601, 1500, p), whole(602, 1500, p));
        let count = |f: fn(&(Table, Table, Table, Table)) -> &Table| -> usize {
            out.iter().map(|o| f(o).num_rows()).sum()
        };
        assert_eq!(count(|o| &o.0), ops::distinct(&a, &[0]).unwrap().num_rows());
        assert_eq!(count(|o| &o.1), ops::intersect(&a, &b).unwrap().num_rows());
        assert_eq!(count(|o| &o.2), ops::difference(&a, &b).unwrap().num_rows());
        assert_eq!(
            count(|o| &o.3),
            ops::union_distinct(&a, &b).unwrap().num_rows()
        );
        // algebra: intersect + difference partition distinct(a)
        assert_eq!(count(|o| &o.1) + count(|o| &o.2), count(|o| &o.0));
    }
}

//! Distributed join: hash-shuffle both sides on their key columns, then
//! run the local join kernel on the co-partitioned pair (paper Fig 2).
//!
//! Because both tables route through the *same* key hasher, equal keys
//! land on the same rank no matter which side they came from; each rank's
//! local join therefore sees every match (and, for outer joins, every
//! non-match) exactly once.

use super::shuffle_by_key;
use crate::error::{Error, Result};
use crate::executor::CylonEnv;
use crate::metrics::Phase;
use crate::ops::{self, JoinOptions};
use crate::table::Table;

/// Distributed join of two partitioned tables. Each rank passes its own
/// partition; the result is the rank's partition of the joined table
/// (co-partitioned by the left key columns).
pub fn join(left: &Table, right: &Table, opts: &JoinOptions, env: &CylonEnv) -> Result<Table> {
    if opts.left_on.is_empty() || opts.left_on.len() != opts.right_on.len() {
        return Err(Error::invalid(
            "dist::join requires equal, non-empty key column lists",
        ));
    }
    let l = shuffle_by_key(left, &opts.left_on, env)?;
    let r = shuffle_by_key(right, &opts.right_on, env)?;
    env.time(Phase::Compute, || {
        ops::join_with_hasher(&l, &r, opts, env.hasher())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen;
    use crate::executor::{Cluster, CylonExecutor};
    use crate::ops::JoinType;

    fn whole(seed: u64, rows: usize, p: usize) -> Table {
        let parts: Vec<Table> = (0..p)
            .map(|r| datagen::partition_for_rank(seed, rows, 0.5, r, p))
            .collect();
        Table::concat(&parts.iter().collect::<Vec<_>>()).unwrap()
    }

    fn dist_rows(p: usize, jt: JoinType) -> usize {
        let c = Cluster::local(p).unwrap();
        let exec = CylonExecutor::new(&c, p).unwrap();
        let out = exec
            .run(move |env| {
                let l = datagen::partition_for_rank(301, 2000, 0.5, env.rank(), env.world_size());
                let r = datagen::partition_for_rank(302, 2000, 0.5, env.rank(), env.world_size());
                let j = join(&l, &r, &JoinOptions::inner(0, 0).with_type(jt), env)?;
                Ok(j.num_rows())
            })
            .unwrap()
            .wait()
            .unwrap();
        out.iter().sum()
    }

    #[test]
    fn inner_and_outer_counts_match_local() {
        let (lall, rall) = (whole(301, 2000, 3), whole(302, 2000, 3));
        for jt in [JoinType::Inner, JoinType::Left, JoinType::Right, JoinType::FullOuter] {
            let reference = ops::join(&lall, &rall, &JoinOptions::inner(0, 0).with_type(jt))
                .unwrap()
                .num_rows();
            assert_eq!(dist_rows(3, jt), reference, "{jt:?}");
        }
    }

    #[test]
    fn rejects_mismatched_keys() {
        let c = Cluster::local(1).unwrap();
        let exec = CylonExecutor::new(&c, 1).unwrap();
        let r = exec
            .run(|env| {
                let t = datagen::uniform_table(1, 10, 0.9);
                let bad = JoinOptions {
                    left_on: vec![0, 1],
                    right_on: vec![0],
                    ..JoinOptions::inner(0, 0)
                };
                join(&t, &t, &bad, env).map(|t| t.num_rows())
            })
            .unwrap()
            .wait();
        assert!(r.is_err());
    }
}

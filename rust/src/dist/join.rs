//! Distributed join: hash-shuffle both sides on their key columns, then
//! run the local join kernel on the co-partitioned pair (paper Fig 2).
//!
//! Because both tables route through the *same* key hasher, equal keys
//! land on the same rank no matter which side they came from; each rank's
//! local join therefore sees every match (and, for outer joins, every
//! non-match) exactly once.

use super::shuffle_by_key;
use crate::error::{Error, Result};
use crate::executor::CylonEnv;
use crate::metrics::Phase;
use crate::ops::{self, JoinOptions};
use crate::table::Table;
use std::borrow::Cow;

/// Which sides of a distributed join still need their key shuffle. The
/// plan optimizer ([`crate::plan`]) passes anything other than
/// [`ExchangeSides::Both`] when partitioning lineage proves a side is
/// already hash-partitioned on exactly its join keys.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExchangeSides {
    /// Shuffle both sides (no lineage information — the safe default).
    #[default]
    Both,
    /// Shuffle only the left side; the right is already co-partitioned.
    LeftOnly,
    /// Shuffle only the right side; the left is already co-partitioned.
    RightOnly,
    /// Shuffle neither side — both are co-partitioned on the keys.
    Neither,
}

impl ExchangeSides {
    /// Does the left side still need its shuffle?
    pub fn shuffles_left(&self) -> bool {
        matches!(self, ExchangeSides::Both | ExchangeSides::LeftOnly)
    }

    /// Does the right side still need its shuffle?
    pub fn shuffles_right(&self) -> bool {
        matches!(self, ExchangeSides::Both | ExchangeSides::RightOnly)
    }
}

/// Distributed join of two partitioned tables. Each rank passes its own
/// partition; the result is the rank's partition of the joined table
/// (co-partitioned by the left key columns for inner/left joins, the
/// right key columns for right joins).
pub fn join(left: &Table, right: &Table, opts: &JoinOptions, env: &CylonEnv) -> Result<Table> {
    join_with_exchange(left, right, opts, ExchangeSides::Both, env)
}

/// [`join`] that elides both shuffles: correct when each side is already
/// hash-partitioned on exactly its join key columns by the gang's shared
/// hasher (e.g. the output of a previous [`join`] or shuffled groupby on
/// the same keys).
pub fn join_prepartitioned(
    left: &Table,
    right: &Table,
    opts: &JoinOptions,
    env: &CylonEnv,
) -> Result<Table> {
    join_with_exchange(left, right, opts, ExchangeSides::Neither, env)
}

/// [`join`] with explicit control over which sides are exchanged — the
/// entry point the plan lowering uses. A side may only skip its shuffle
/// when its rows are already routed by `hash(keys) mod world_size` under
/// the gang hasher; the caller (normally the lineage pass) is
/// responsible for that proof.
pub fn join_with_exchange(
    left: &Table,
    right: &Table,
    opts: &JoinOptions,
    exchange: ExchangeSides,
    env: &CylonEnv,
) -> Result<Table> {
    if opts.left_on.is_empty() || opts.left_on.len() != opts.right_on.len() {
        return Err(Error::invalid(
            "dist::join requires equal, non-empty key column lists",
        ));
    }
    // An elided side is used in place — no copy, that is the point of
    // the elision.
    let l: Cow<'_, Table> = if exchange.shuffles_left() {
        Cow::Owned(shuffle_by_key(left, &opts.left_on, env)?)
    } else {
        Cow::Borrowed(left)
    };
    let r: Cow<'_, Table> = if exchange.shuffles_right() {
        Cow::Owned(shuffle_by_key(right, &opts.right_on, env)?)
    } else {
        Cow::Borrowed(right)
    };
    env.time(Phase::Compute, || {
        ops::join_with_pool(&l, &r, opts, env.hasher(), env.pool())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen;
    use crate::executor::{Cluster, CylonExecutor};
    use crate::ops::JoinType;

    fn whole(seed: u64, rows: usize, p: usize) -> Table {
        let parts: Vec<Table> = (0..p)
            .map(|r| datagen::partition_for_rank(seed, rows, 0.5, r, p))
            .collect();
        Table::concat_owned(parts).unwrap()
    }

    fn dist_rows(p: usize, jt: JoinType) -> usize {
        let c = Cluster::local(p).unwrap();
        let exec = CylonExecutor::new(&c, p).unwrap();
        let out = exec
            .run(move |env| {
                let l = datagen::partition_for_rank(301, 2000, 0.5, env.rank(), env.world_size());
                let r = datagen::partition_for_rank(302, 2000, 0.5, env.rank(), env.world_size());
                let j = join(&l, &r, &JoinOptions::inner(0, 0).with_type(jt), env)?;
                Ok(j.num_rows())
            })
            .unwrap()
            .wait()
            .unwrap();
        out.iter().sum()
    }

    #[test]
    fn inner_and_outer_counts_match_local() {
        let (lall, rall) = (whole(301, 2000, 3), whole(302, 2000, 3));
        for jt in [JoinType::Inner, JoinType::Left, JoinType::Right, JoinType::FullOuter] {
            let reference = ops::join(&lall, &rall, &JoinOptions::inner(0, 0).with_type(jt))
                .unwrap()
                .num_rows();
            assert_eq!(dist_rows(3, jt), reference, "{jt:?}");
        }
    }

    #[test]
    fn partial_exchange_matches_full_shuffle() {
        // A ⋈ B on key 0 leaves the result co-partitioned on key 0, so a
        // second join against a fresh table only needs to shuffle that
        // fresh (right) side.
        let p = 3;
        let c = Cluster::local(p).unwrap();
        let exec = CylonExecutor::new(&c, p).unwrap();
        let out = exec
            .run(|env| {
                let a = datagen::partition_for_rank(311, 1500, 0.4, env.rank(), env.world_size());
                let b = datagen::partition_for_rank(312, 1500, 0.4, env.rank(), env.world_size());
                let cc = datagen::partition_for_rank(313, 1500, 0.4, env.rank(), env.world_size());
                let ab = join(&a, &b, &JoinOptions::inner(0, 0), env)?;
                let elided = join_with_exchange(
                    &ab,
                    &cc,
                    &JoinOptions::inner(0, 0),
                    ExchangeSides::RightOnly,
                    env,
                )?;
                let full = join(&ab, &cc, &JoinOptions::inner(0, 0), env)?;
                Ok((elided.num_rows(), full.num_rows()))
            })
            .unwrap()
            .wait()
            .unwrap();
        let elided: usize = out.iter().map(|(e, _)| e).sum();
        let full: usize = out.iter().map(|(_, f)| f).sum();
        assert_eq!(elided, full, "shuffle elision changed the join result");
        assert!(ExchangeSides::Both.shuffles_left() && ExchangeSides::Both.shuffles_right());
        assert!(!ExchangeSides::Neither.shuffles_left());
        assert!(!ExchangeSides::LeftOnly.shuffles_right());
    }

    #[test]
    fn rejects_mismatched_keys() {
        let c = Cluster::local(1).unwrap();
        let exec = CylonExecutor::new(&c, 1).unwrap();
        let r = exec
            .run(|env| {
                let t = datagen::uniform_table(1, 10, 0.9);
                let bad = JoinOptions {
                    left_on: vec![0, 1],
                    right_on: vec![0],
                    ..JoinOptions::inner(0, 0)
                };
                join(&t, &t, &bad, env).map(|t| t.num_rows())
            })
            .unwrap()
            .wait();
        assert!(r.is_err());
    }
}

//! Distributed sample sort (paper Fig 8, third panel): oversample locally
//! → allgather the sample → derive `p − 1` splitters → range-partition →
//! all-to-all → local sort. After the exchange, rank `i` holds exactly
//! the rows between splitters `i − 1` and `i`, so concatenating the rank
//! outputs in rank order yields the globally sorted table.

use crate::error::{Error, Result};
use crate::executor::CylonEnv;
use crate::metrics::Phase;
use crate::ops::{self, SortOptions};
use crate::table::Table;

/// Rows each rank contributes to the splitter sample per peer (the
/// oversampling factor; higher = tighter balance, larger allgather).
const SAMPLE_PER_RANK: usize = 32;

/// Distributed sort. Each rank passes its partition and receives its
/// globally-ordered slice, locally sorted under `opts` (multi-key,
/// per-key direction, nulls-first ascending — same semantics as
/// [`fn@ops::sort`]).
pub fn sort(t: &Table, opts: &SortOptions, env: &CylonEnv) -> Result<Table> {
    check_sort_keys(t, opts)?;
    let p = env.world_size();
    if p == 1 {
        return env.time(Phase::Compute, || ops::sort_with_pool(t, opts, env.pool()));
    }
    let key_cols: Vec<usize> = opts.keys.iter().map(|k| k.col).collect();
    let dirs: Vec<bool> = opts.keys.iter().map(|k| k.ascending).collect();

    // 1. Oversampled local sample (auxiliary), gathered everywhere.
    let sample = env.time(Phase::Auxiliary, || {
        ops::sample_rows(t, (SAMPLE_PER_RANK * p).max(64), 0x5a3d ^ env.rank() as u64)
    });
    let global_sample = env.comm().allgather_streamed(&sample)?;

    // 2. Splitters: sort the global sample under the *real* options (so
    // descending / multi-key orders produce correctly-directed ranges)
    // and take p − 1 evenly spaced key rows.
    let splitters = env.time(Phase::Auxiliary, || -> Result<Table> {
        let idx = ops::sort::sort_indices(&global_sample, opts)?;
        let sorted = global_sample.gather(&idx).project(&key_cols)?;
        let n = sorted.num_rows();
        if n == 0 {
            return Ok(sorted.slice(0, 0));
        }
        let picks: Vec<u32> = (1..p).map(|i| ((i * n) / p).min(n - 1) as u32).collect();
        Ok(sorted.gather(&picks))
    })?;

    // 3. Range partition under the directed order (splitter column i
    // holds sort key i; ties always land in the same bucket, so equal
    // rows never straddle a rank boundary inconsistently). Pad to p
    // buckets when the sample was too small to produce p − 1 splitters.
    let splitter_cols: Vec<usize> = (0..key_cols.len()).collect();
    let mut parts = env.time(Phase::Auxiliary, || {
        ops::partition_by_range_directed(t, &key_cols, &splitters, &splitter_cols, &dirs)
    })?;
    while parts.len() < p {
        parts.push(t.slice(0, 0));
    }

    // 4. Exchange (streaming: oversized sorts spill at the receiver),
    // then the core local sort on the received slice.
    let mine = env.comm().shuffle_streamed(parts)?;
    env.time(Phase::Compute, || ops::sort_with_pool(&mine, opts, env.pool()))
}

/// Sort that elides the sample/exchange entirely: a pure local sort,
/// correct when the partitions are already *range-partitioned* in rank
/// order on a key list prefix-compatible with `opts` (e.g. the output of
/// a previous [`sort`] on the same leading keys and directions) — every
/// row on rank `i` then precedes every row on rank `i+1` under `opts`,
/// so the rank-ordered concatenation of the local sorts is globally
/// sorted. The caller (normally the plan lineage pass) owns that proof.
pub fn sort_prepartitioned(t: &Table, opts: &SortOptions, env: &CylonEnv) -> Result<Table> {
    check_sort_keys(t, opts)?;
    env.time(Phase::Compute, || ops::sort_with_pool(t, opts, env.pool()))
}

/// Shared argument check: non-empty key list, all key columns present.
pub(crate) fn check_sort_keys(t: &Table, opts: &SortOptions) -> Result<()> {
    if opts.keys.is_empty() {
        return Err(Error::invalid("dist::sort: empty key list"));
    }
    for k in &opts.keys {
        t.column(k.col)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen;
    use crate::executor::{Cluster, CylonExecutor};
    use crate::ops::SortKey;

    #[test]
    fn global_order_and_conservation() {
        let p = 4;
        let c = Cluster::local(p).unwrap();
        let exec = CylonExecutor::new(&c, p).unwrap();
        let out = exec
            .run(|env| {
                let t = datagen::partition_for_rank(501, 4000, 0.9, env.rank(), env.world_size());
                sort(&t, &SortOptions::by(0), env)
            })
            .unwrap()
            .wait()
            .unwrap();
        let total: usize = out.iter().map(|t| t.num_rows()).sum();
        assert_eq!(total, 4000);
        let mut last = i64::MIN;
        for t in &out {
            for &k in t.column(0).unwrap().i64_values().unwrap() {
                assert!(k >= last, "global order violated");
                last = k;
            }
        }
    }

    #[test]
    fn multi_key_mixed_directions() {
        let p = 3;
        let opts = SortOptions {
            keys: vec![SortKey::asc(0), SortKey::desc(1)],
            stable: false,
        };
        let o2 = opts.clone();
        let c = Cluster::local(p).unwrap();
        let exec = CylonExecutor::new(&c, p).unwrap();
        let out = exec
            .run(move |env| {
                let t = datagen::partition_for_rank(502, 3000, 0.05, env.rank(), env.world_size());
                sort(&t, &o2, env)
            })
            .unwrap()
            .wait()
            .unwrap();
        let all = Table::concat_owned(out).unwrap();
        assert!(ops::sort::is_sorted(&all, &opts), "concatenation not globally sorted");
        assert_eq!(all.num_rows(), 3000);
    }

    #[test]
    fn prepartitioned_resort_preserves_global_order() {
        // sort by [0↑,1↓] range-partitions on a [0↑] prefix; re-sorting by
        // [0↑] alone needs no exchange.
        let p = 3;
        let c = Cluster::local(p).unwrap();
        let exec = CylonExecutor::new(&c, p).unwrap();
        let out = exec
            .run(|env| {
                let t = datagen::partition_for_rank(503, 2000, 0.1, env.rank(), env.world_size());
                let opts = SortOptions {
                    keys: vec![SortKey::asc(0), SortKey::desc(1)],
                    stable: false,
                };
                let s = sort(&t, &opts, env)?;
                sort_prepartitioned(&s, &SortOptions::by(0), env)
            })
            .unwrap()
            .wait()
            .unwrap();
        let all = Table::concat_owned(out).unwrap();
        assert_eq!(all.num_rows(), 2000);
        assert!(ops::sort::is_sorted(&all, &SortOptions::by(0)));
    }

    #[test]
    fn empty_partitions_are_fine() {
        let p = 3;
        let c = Cluster::local(p).unwrap();
        let exec = CylonExecutor::new(&c, p).unwrap();
        let out = exec
            .run(|env| {
                // only rank 0 holds data
                let t = if env.rank() == 0 {
                    datagen::uniform_table(7, 500, 0.9)
                } else {
                    datagen::uniform_table(7, 500, 0.9).slice(0, 0)
                };
                sort(&t, &SortOptions::by(0), env)
            })
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(out.iter().map(|t| t.num_rows()).sum::<usize>(), 500);
        let mut last = i64::MIN;
        for t in &out {
            for &k in t.column(0).unwrap().i64_values().unwrap() {
                assert!(k >= last);
                last = k;
            }
        }
    }
}

//! Distributed `describe`: per-column summary statistics over the whole
//! logical table. Each rank computes local stats (core local operator),
//! encodes them as a tiny stats table, and an allgather + local merge
//! yields identical global stats on every rank — the classic
//! tree-reducible aggregate, so no raw data moves.

use crate::column::ColumnBuilder;
use crate::error::{Error, Result};
use crate::executor::CylonEnv;
use crate::metrics::Phase;
use crate::ops::{self, ColumnStats};
use crate::table::Table;
use crate::types::DType;

/// Distributed column statistics: every rank returns the same global
/// [`ColumnStats`] per column (count/nulls/sum/min/max/mean), equal to
/// running [`fn@ops::describe`] on the concatenated table.
pub fn describe(t: &Table, env: &CylonEnv) -> Result<Vec<ColumnStats>> {
    let local = env.time(Phase::Compute, || ops::describe(t))?;
    if env.world_size() == 1 {
        return Ok(local);
    }
    let stats_t = env.time(Phase::Auxiliary, || stats_to_table(&local))?;
    let all = env.comm().allgather_streamed(&stats_t)?;
    env.time(Phase::Auxiliary, || merge_stats(t, &all))
}

/// Encode per-column stats as rows of `(col, count, nulls, sum, min, max)`
/// — nullable floats carry the "no numeric data" case across the wire.
fn stats_to_table(stats: &[ColumnStats]) -> Result<Table> {
    let mut col = ColumnBuilder::with_capacity(DType::Int64, stats.len());
    let mut count = ColumnBuilder::with_capacity(DType::Int64, stats.len());
    let mut nulls = ColumnBuilder::with_capacity(DType::Int64, stats.len());
    let mut sum = ColumnBuilder::with_capacity(DType::Float64, stats.len());
    let mut min = ColumnBuilder::with_capacity(DType::Float64, stats.len());
    let mut max = ColumnBuilder::with_capacity(DType::Float64, stats.len());
    for (i, s) in stats.iter().enumerate() {
        col.push_i64(i as i64);
        count.push_i64(s.count as i64);
        nulls.push_i64(s.nulls as i64);
        for (b, v) in [(&mut sum, s.sum), (&mut min, s.min), (&mut max, s.max)] {
            match v {
                Some(x) => b.push_f64(x),
                None => b.push_null(),
            }
        }
    }
    Table::from_columns(vec![
        ("col", col.finish()),
        ("count", count.finish()),
        ("nulls", nulls.finish()),
        ("sum", sum.finish()),
        ("min", min.finish()),
        ("max", max.finish()),
    ])
}

fn merge_stats(t: &Table, all: &Table) -> Result<Vec<ColumnStats>> {
    let m = t.num_columns();
    let mut out = Vec::with_capacity(m);
    for i in 0..m {
        out.push(ColumnStats {
            name: t.schema().field(i)?.name.clone(),
            count: 0,
            nulls: 0,
            sum: None,
            min: None,
            max: None,
            mean: None,
        });
    }
    for r in 0..all.num_rows() {
        let ci = all
            .value(r, 0)?
            .as_i64()
            .ok_or_else(|| Error::invalid("malformed stats row"))? as usize;
        if ci >= m {
            continue;
        }
        let s = &mut out[ci];
        s.count += all.value(r, 1)?.as_i64().unwrap_or(0) as usize;
        s.nulls += all.value(r, 2)?.as_i64().unwrap_or(0) as usize;
        if let Some(x) = all.value(r, 3)?.as_f64() {
            s.sum = Some(s.sum.unwrap_or(0.0) + x);
        }
        if let Some(x) = all.value(r, 4)?.as_f64() {
            s.min = Some(s.min.map_or(x, |cur| cur.min(x)));
        }
        if let Some(x) = all.value(r, 5)?.as_f64() {
            s.max = Some(s.max.map_or(x, |cur| cur.max(x)));
        }
    }
    for s in &mut out {
        s.mean = match (s.sum, s.count > 0) {
            (Some(x), true) => Some(x / s.count as f64),
            _ => None,
        };
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen;
    use crate::executor::{Cluster, CylonExecutor};

    #[test]
    fn matches_local_reference_on_every_rank() {
        let p = 3;
        let c = Cluster::local(p).unwrap();
        let exec = CylonExecutor::new(&c, p).unwrap();
        let out = exec
            .run(|env| {
                let t = datagen::partition_for_rank(701, 2400, 0.9, env.rank(), env.world_size());
                describe(&t, env)
            })
            .unwrap()
            .wait()
            .unwrap();
        let parts: Vec<Table> = (0..p)
            .map(|r| datagen::partition_for_rank(701, 2400, 0.9, r, p))
            .collect();
        let whole = Table::concat_owned(parts).unwrap();
        let reference = ops::describe(&whole).unwrap();
        for rank_stats in &out {
            assert_eq!(rank_stats.len(), reference.len());
            for (got, want) in rank_stats.iter().zip(&reference) {
                assert_eq!(got.name, want.name);
                assert_eq!(got.count, want.count);
                assert_eq!(got.nulls, want.nulls);
                assert_eq!(got.sum, want.sum);
                assert_eq!(got.min, want.min);
                assert_eq!(got.max, want.max);
            }
        }
    }
}

//! Distributed groupby with two strategies (the paper's §VI ablation):
//!
//! - **Shuffle-first**: hash-shuffle raw rows on the key columns, then run
//!   the local groupby. Moves all data; right for high-cardinality keys
//!   where partial aggregation would barely shrink the payload.
//! - **Two-phase** (default): run a *partial* local groupby, shuffle the
//!   much smaller partials, merge, and finalize the algebraic aggregates
//!   (Mean = sum/count, Var/Std from (sum, count, sumsq)). Right for
//!   low/medium cardinality where partials collapse the shuffle volume.

use super::{check_keys, shuffle_by_key};
use crate::column::ColumnBuilder;
use crate::error::Result;
use crate::executor::CylonEnv;
use crate::metrics::Phase;
use crate::ops::{self, AggFun, AggSpec};
use crate::table::Table;
use crate::types::{DType, Field, Schema};
use std::fmt;

/// How the distributed groupby moves data (paper §VI groupby ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum GroupbyStrategy {
    /// Partial-aggregate locally, shuffle partials, merge + finalize.
    #[default]
    TwoPhase,
    /// Shuffle raw rows on the keys, then aggregate locally.
    ShuffleFirst,
}

impl fmt::Display for GroupbyStrategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            GroupbyStrategy::TwoPhase => "two-phase",
            GroupbyStrategy::ShuffleFirst => "shuffle-first",
        })
    }
}

/// Distributed groupby: each rank passes its partition and receives the
/// complete rows for the keys that hash to it. Output schema matches the
/// local [`fn@ops::groupby`]: key columns, then one `{fun}_{col}` column per
/// aggregate.
pub fn groupby(
    t: &Table,
    key_cols: &[usize],
    aggs: &[AggSpec],
    strategy: GroupbyStrategy,
    env: &CylonEnv,
) -> Result<Table> {
    check_keys(t, key_cols, "dist::groupby")?;
    match strategy {
        GroupbyStrategy::ShuffleFirst => {
            // Skew-aware path (DESIGN.md §8): when enabled and hot keys
            // are detected, the raw-row shuffle is salted for balance
            // and hot groups are rebuilt via the two-phase machinery —
            // the output keeps the co-location contract either way.
            if let Some(out) = super::skew::groupby_shuffle_first_balanced(t, key_cols, aggs, env)?
            {
                return Ok(out);
            }
            let shuffled = shuffle_by_key(t, key_cols, env)?;
            env.time(Phase::Compute, || {
                ops::groupby_with_pool(&shuffled, key_cols, aggs, env.hasher(), env.pool())
            })
        }
        GroupbyStrategy::TwoPhase => groupby_two_phase(t, key_cols, aggs, env),
    }
}

/// Groupby that elides the shuffle entirely: correct when the input is
/// already co-partitioned on `key_cols` (e.g. the output of
/// [`fn@super::join`] keyed on the same columns) — the zero-communication
/// reuse the paper's pipeline leans on.
pub fn groupby_prepartitioned(
    t: &Table,
    key_cols: &[usize],
    aggs: &[AggSpec],
    env: &CylonEnv,
) -> Result<Table> {
    check_keys(t, key_cols, "dist::groupby_prepartitioned")?;
    env.time(Phase::Compute, || {
        ops::groupby_with_pool(t, key_cols, aggs, env.hasher(), env.pool())
    })
}

/// The two-phase core: partial-aggregate locally, shuffle the partials
/// co-partitioned on the keys, merge, finalize. Also the *rebuild* step
/// of the skew-aware shuffle-first groupby ([`crate::dist::skew`]),
/// applied there to just the hot-key rows.
pub(crate) fn groupby_two_phase(
    t: &Table,
    key_cols: &[usize],
    aggs: &[AggSpec],
    env: &CylonEnv,
) -> Result<Table> {
    let nk = key_cols.len();
    // Decompose every aggregate into shuffle-able partials; `offsets[i]`
    // is where aggregate i's partial columns start (after the keys).
    let mut expanded: Vec<AggSpec> = Vec::new();
    let mut offsets: Vec<usize> = Vec::with_capacity(aggs.len());
    for a in aggs {
        offsets.push(expanded.len());
        for pf in ops::groupby::partial_aggs(a.fun) {
            expanded.push(AggSpec::new(a.col, pf));
        }
    }

    // Phase 1: local partial aggregation (core local operator).
    let partial = env.time(Phase::Compute, || {
        ops::groupby_with_pool(t, key_cols, &expanded, env.hasher(), env.pool())
    })?;

    // Phase 2: shuffle the partials on the (now leading) key columns.
    let key_idx: Vec<usize> = (0..nk).collect();
    let shuffled = shuffle_by_key(&partial, &key_idx, env)?;

    // Phase 3: merge partials of the same key (sum of sums, min of mins…).
    let merge_specs: Vec<AggSpec> = expanded
        .iter()
        .enumerate()
        .map(|(j, s)| AggSpec::new(nk + j, ops::groupby::merge_fun(s.fun)))
        .collect();
    let merged = env.time(Phase::Compute, || {
        ops::groupby_with_pool(&shuffled, &key_idx, &merge_specs, env.hasher(), env.pool())
    })?;

    // Phase 4: finalize — rename pass-through partials and compute the
    // algebraic aggregates, reproducing the local kernel's output schema.
    env.time(Phase::Auxiliary, || finalize(t, aggs, &offsets, nk, &merged))
}

fn finalize(
    t: &Table,
    aggs: &[AggSpec],
    offsets: &[usize],
    nk: usize,
    merged: &Table,
) -> Result<Table> {
    let ngroups = merged.num_rows();
    let mut schema = Schema::default();
    let mut columns = Vec::with_capacity(nk + aggs.len());
    for i in 0..nk {
        schema = schema.with_field(merged.schema().field(i)?.clone());
        columns.push(merged.column(i)?.clone());
    }
    for (a, &off) in aggs.iter().zip(offsets) {
        let src_name = &t.schema().field(a.col)?.name;
        let name = format!("{}_{}", a.fun.label(), src_name);
        match a.fun {
            AggFun::Sum | AggFun::Count | AggFun::Min | AggFun::Max | AggFun::SumSq => {
                // A single merged partial IS the final value (dtype already
                // matches the local kernel's output dtype rules).
                let col = merged.column(nk + off)?.clone();
                schema = schema.with_field(Field::new(name, col.dtype()));
                columns.push(col);
            }
            AggFun::Mean | AggFun::Var | AggFun::Std => {
                let sum_c = merged.column(nk + off)?;
                let cnt_c = merged.column(nk + off + 1)?;
                let mut b = ColumnBuilder::with_capacity(DType::Float64, ngroups);
                for g in 0..ngroups {
                    let cnt = cnt_c.value(g).as_f64().unwrap_or(0.0);
                    if cnt <= 0.0 || !sum_c.is_valid(g) {
                        b.push_null();
                        continue;
                    }
                    let sum = sum_c.value(g).as_f64().unwrap_or(0.0);
                    let mean = sum / cnt;
                    let v = match a.fun {
                        AggFun::Mean => mean,
                        // same expression order as the local kernel so the
                        // float results are bit-identical
                        AggFun::Var | AggFun::Std => {
                            let ssq = merged
                                .column(nk + off + 2)?
                                .value(g)
                                .as_f64()
                                .unwrap_or(0.0);
                            let var = (ssq / cnt - mean * mean).max(0.0);
                            if a.fun == AggFun::Std {
                                var.sqrt()
                            } else {
                                var
                            }
                        }
                        _ => unreachable!("matched above"),
                    };
                    b.push_f64(v);
                }
                schema = schema.with_field(Field::new(name, DType::Float64));
                columns.push(b.finish());
            }
        }
    }
    Table::new(schema, columns)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen;
    use crate::executor::{Cluster, CylonExecutor};
    use std::collections::BTreeMap;

    fn whole(seed: u64, rows: usize, card: f64, p: usize) -> Table {
        let parts: Vec<Table> = (0..p)
            .map(|r| datagen::partition_for_rank(seed, rows, card, r, p))
            .collect();
        Table::concat_owned(parts).unwrap()
    }

    fn key_map(t: &Table, val_col: usize) -> BTreeMap<i64, crate::types::Value> {
        (0..t.num_rows())
            .map(|r| {
                (
                    t.value(r, 0).unwrap().as_i64().unwrap(),
                    t.value(r, val_col).unwrap(),
                )
            })
            .collect()
    }

    #[test]
    fn two_phase_algebraic_aggs_match_local_exactly() {
        let p = 3;
        let aggs = [
            AggSpec::new(1, AggFun::Sum),
            AggSpec::new(1, AggFun::Mean),
            AggSpec::new(1, AggFun::Min),
            AggSpec::new(1, AggFun::Count),
        ];
        let c = Cluster::local(p).unwrap();
        let exec = CylonExecutor::new(&c, p).unwrap();
        let out = exec
            .run(move |env| {
                let t = datagen::partition_for_rank(401, 3000, 0.1, env.rank(), env.world_size());
                groupby(&t, &[0], &aggs, GroupbyStrategy::TwoPhase, env)
            })
            .unwrap()
            .wait()
            .unwrap();
        let dist_all = Table::concat_owned(out).unwrap();
        let reference = ops::groupby(&whole(401, 3000, 0.1, p), &[0], &aggs).unwrap();
        assert_eq!(dist_all.num_rows(), reference.num_rows());
        for v in 1..=aggs.len() {
            assert_eq!(key_map(&dist_all, v), key_map(&reference, v), "agg col {v}");
        }
        // schema names reproduce the local kernel's convention
        assert_eq!(dist_all.schema().field(1).unwrap().name, "sum_v");
        assert_eq!(dist_all.schema().field(2).unwrap().name, "mean_v");
    }

    #[test]
    fn strategies_agree() {
        let p = 2;
        let aggs = [AggSpec::new(1, AggFun::Sum)];
        let run = |strategy: GroupbyStrategy| -> BTreeMap<i64, crate::types::Value> {
            let c = Cluster::local(p).unwrap();
            let exec = CylonExecutor::new(&c, p).unwrap();
            let out = exec
                .run(move |env| {
                    let t =
                        datagen::partition_for_rank(402, 2000, 0.3, env.rank(), env.world_size());
                    groupby(&t, &[0], &aggs, strategy, env)
                })
                .unwrap()
                .wait()
                .unwrap();
            key_map(&Table::concat_owned(out).unwrap(), 1)
        };
        assert_eq!(run(GroupbyStrategy::TwoPhase), run(GroupbyStrategy::ShuffleFirst));
    }

    #[test]
    fn prepartitioned_after_join_has_no_split_groups() {
        let p = 3;
        let c = Cluster::local(p).unwrap();
        let exec = CylonExecutor::new(&c, p).unwrap();
        let out = exec
            .run(|env| {
                let l = datagen::partition_for_rank(403, 2000, 0.2, env.rank(), env.world_size());
                let r = datagen::partition_for_rank(404, 2000, 0.2, env.rank(), env.world_size());
                let j = super::super::join(&l, &r, &crate::ops::JoinOptions::inner(0, 0), env)?;
                groupby_prepartitioned(&j, &[0], &[AggSpec::new(1, AggFun::Count)], env)
            })
            .unwrap()
            .wait()
            .unwrap();
        // a key must appear on exactly one rank (otherwise the shuffle
        // elision would double-count groups)
        let mut seen = std::collections::BTreeSet::new();
        for t in &out {
            for &k in t.column(0).unwrap().i64_values().unwrap() {
                assert!(seen.insert(k), "group {k} split across ranks");
            }
        }
    }

    #[test]
    fn display_labels() {
        assert_eq!(GroupbyStrategy::TwoPhase.to_string(), "two-phase");
        assert_eq!(GroupbyStrategy::ShuffleFirst.to_string(), "shuffle-first");
        assert_eq!(GroupbyStrategy::default(), GroupbyStrategy::TwoPhase);
    }
}

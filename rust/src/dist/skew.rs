//! Skew-aware repartitioning — the paper's §VI answer to "skewed
//! datasets could starve some processes" (see also Perera et al.,
//! arXiv:2209.06146, which attributes Cylon's edge on skewed keys to
//! balanced partition construction).
//!
//! A plain hash shuffle routes every row of a key to `hash(key) mod p`;
//! one dominant key therefore lands on one rank, and the BSP step waits
//! on that rank. This module detects such *hot keys* from a cheap
//! oversampled allgather (the same collective the sample sort already
//! pays for), builds a [`SkewPlan`] — a split-assignment that salts each
//! hot key across a **contiguous rank range** sized to its estimated
//! share — and threads the plan through the exchanges:
//!
//! - [`shuffle_by_key_balanced`]: salted shuffle. Hot keys end up split
//!   across their range; callers must not assume key co-location.
//! - [`join_skew`]: salts the dominant side of each hot key and
//!   **replicates** the other side's rows for that key across the same
//!   range, so every match is still produced exactly once (the build
//!   side is order-insensitive — no rebuild needed). When one side's hot
//!   key dominates and the other side is small, it falls back to a
//!   broadcast join: the small side is allgathered, the big skewed side
//!   never crosses the wire.
//! - the shuffle-first [`crate::dist::groupby()`] (via the crate-internal
//!   `groupby_shuffle_first_balanced`):
//!   salted raw shuffle, then a *rebuild*: cold keys aggregate directly
//!   (all their rows co-located as usual), hot keys run the two-phase
//!   partial/merge machinery so their final groups land back on their
//!   owner rank — the output keeps the strict co-location contract.
//!   Two-phase groupby needs no treatment at all: its partials carry at
//!   most one row per key per rank, so the partial shuffle is balanced
//!   by construction and the estimator finds nothing hot in it.
//! - [`sort_balanced`]: run-aware splitter derivation keeps duplicate
//!   splitters for hot runs, and the tie-spreading range partitioner
//!   ([`crate::ops::partition_by_range_directed_spread`]) round-robins
//!   tied rows across the bucket range those duplicates open — global
//!   sortedness is preserved, co-location of equal keys is not.
//!
//! The plan optimizer records the weakened placement of skew-split
//! exchanges through the `balanced` flag on
//! [`crate::plan::Partitioning`], so shuffle elision never fires on an
//! output whose hot keys may be split.
//!
//! Everything here is SPMD-safe by construction: every decision is
//! derived from *globally identical* data (allgathered samples,
//! allreduced counts), so all ranks take the same branches and call the
//! same collectives in the same order. The whole subsystem is gated by
//! [`crate::config::SkewConfig`] (`CYLONFLOW_SKEW` et al.) and reports
//! what it did through [`crate::metrics::SkewStats`].

use super::{check_keys, ExchangeSides};
use crate::column::Column;
use crate::error::{Error, Result};
use crate::executor::CylonEnv;
use crate::metrics::{Phase, SkewStats};
use crate::ops::{self, JoinOptions, JoinType, KeyHasher, SortOptions};
use crate::table::Table;
use std::collections::{BTreeMap, BTreeSet};

/// Minimum raw sample occurrences before a key may be declared hot —
/// guards against a tiny sample promoting noise into a reroute plan.
const MIN_HOT_SAMPLES: u64 = 4;

/// Seed mixed into the per-rank frequency-estimation sample.
const SAMPLE_SEED: u64 = 0x5eed_cafe;

/// Where a hot key's rows go: the contiguous rank range
/// `[start, start + span)`, filled round-robin by the salting
/// partitioner (or entirely, by the replicating partitioner).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HotRange {
    /// First rank of the range.
    pub start: usize,
    /// Number of consecutive ranks the key is split over.
    pub span: usize,
    /// Estimated share of the exchanged rows this key holds (for
    /// reports; the routing itself only needs `start`/`span`).
    pub share: f64,
}

/// A split-assignment plan: which key hashes are hot and which
/// contiguous rank range each one is spread over. Identical on every
/// rank (it is a pure function of the allgathered sample), which is what
/// makes the salted routing SPMD-correct.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SkewPlan {
    /// Hot key hash → assigned rank range.
    pub hot: BTreeMap<i64, HotRange>,
}

impl SkewPlan {
    /// True when nothing was flagged hot (plain hashing suffices).
    pub fn is_empty(&self) -> bool {
        self.hot.is_empty()
    }

    /// Number of hot key-hash groups in the plan.
    pub fn len(&self) -> usize {
        self.hot.len()
    }
}

/// Per-key frequency estimate gathered from every rank's sample, with
/// each sampled row weighted by the rows it represents (rank rows /
/// rank sample size), so unequal partitions don't bias the shares.
#[derive(Debug, Clone)]
pub struct KeyEstimate {
    /// Key hash → (estimated rows, raw sample occurrences).
    counts: BTreeMap<i64, (f64, u64)>,
    /// Estimated total rows across the gang (sum of weights).
    total: f64,
}

impl KeyEstimate {
    /// Estimated global row count.
    pub fn total_rows(&self) -> f64 {
        self.total
    }

    /// Estimated share of key hash `h` (0 when unseen).
    pub fn share(&self, h: i64) -> f64 {
        if self.total <= 0.0 {
            return 0.0;
        }
        self.counts.get(&h).map(|(w, _)| w / self.total).unwrap_or(0.0)
    }

    /// Largest single-key share in the estimate.
    pub fn max_share(&self) -> f64 {
        if self.total <= 0.0 {
            return 0.0;
        }
        self.counts
            .values()
            .map(|(w, _)| w / self.total)
            .fold(0.0, f64::max)
    }

    /// Keys whose estimated share exceeds `threshold × (1/p)`, with
    /// enough raw sample support to trust (`≥ MIN_HOT_SAMPLES`).
    pub fn hot_keys(&self, threshold: f64, p: usize) -> Vec<(i64, f64)> {
        if self.total <= 0.0 {
            return Vec::new();
        }
        let cut = threshold / p as f64;
        let mut hot: Vec<(i64, f64)> = self
            .counts
            .iter()
            .filter(|(_, (_, raw))| *raw >= MIN_HOT_SAMPLES)
            .map(|(h, (w, _))| (*h, w / self.total))
            .filter(|(_, share)| *share > cut)
            .collect();
        sort_heaviest_first(&mut hot);
        hot
    }

    /// Estimated *cold* rows landing on each rank under plain
    /// `hash mod p` routing, excluding the keys in `hot` (those are
    /// placed by the greedy assignment instead). Scaled to shares of the
    /// total, so it composes with hot shares in the load model.
    pub fn cold_shares(&self, hot: &BTreeSet<i64>, p: usize) -> Vec<f64> {
        let mut load = vec![0.0; p];
        if self.total <= 0.0 {
            return load;
        }
        for (h, (w, _)) in &self.counts {
            if !hot.contains(h) {
                load[(*h as u64 % p as u64) as usize] += w / self.total;
            }
        }
        load
    }
}

/// Descending by share, hash tiebreak — the one comparator every rank
/// must apply identically for the greedy assignment to be SPMD-safe.
fn heavier_first(a: (i64, f64), b: (i64, f64)) -> std::cmp::Ordering {
    let ord = b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal);
    ord.then(a.0.cmp(&b.0))
}

/// Sort a hot-key list heaviest-first (see [`heavier_first`]).
fn sort_heaviest_first(hot: &mut [(i64, f64)]) {
    hot.sort_by(|a, b| heavier_first(*a, *b));
}

/// Estimate per-key frequencies of `t`'s shuffle keys across the gang:
/// each rank samples `sample_per_rank` rows, hashes the key columns with
/// the gang hasher, and allgathers `(hash, weight)` pairs. The result is
/// identical on every rank. One collective; payload is a few KiB.
pub fn estimate_keys(t: &Table, key_cols: &[usize], env: &CylonEnv) -> Result<KeyEstimate> {
    let cfg = &env.comm().exchange_config().skew;
    let k = cfg.sample_per_rank.max(1);
    let (hashes, weight) = env.time(Phase::Auxiliary, || -> Result<(Vec<i64>, f64)> {
        let sample = ops::sample_rows(t, k, SAMPLE_SEED ^ env.rank() as u64);
        let hashes = ops::kernels::row_hashes(&sample, key_cols, env.hasher())?;
        let w = if sample.num_rows() == 0 {
            0.0
        } else {
            t.num_rows() as f64 / sample.num_rows() as f64
        };
        Ok((hashes, w))
    })?;
    let n = hashes.len();
    let local = Table::from_columns(vec![
        ("h", Column::from_i64(hashes)),
        ("w", Column::from_f64(vec![weight; n])),
    ])?;
    let global = env.comm().allgather_streamed(&local)?;
    let hs = global.column(0)?.i64_values()?;
    let ws = global.column(1)?.f64_values()?;
    let mut counts: BTreeMap<i64, (f64, u64)> = BTreeMap::new();
    let mut total = 0.0;
    for (&h, &w) in hs.iter().zip(ws) {
        let e = counts.entry(h).or_insert((0.0, 0));
        e.0 += w;
        e.1 += 1;
        total += w;
    }
    Ok(KeyEstimate { counts, total })
}

/// Greedily place hot keys onto contiguous rank ranges over a base load
/// (estimated cold rows per rank): heaviest key first, span proportional
/// to its share (both `floor` and `ceil` of `share × p` are candidates —
/// a narrower range concentrating slightly above the fair share often
/// beats a wider one that must overlap other hot ranges), start chosen
/// to minimize the resulting maximum load. Pure and deterministic —
/// every rank computes the identical plan from the identical estimate.
pub fn assign_ranges(hot: &[(i64, f64)], cold: &[f64], p: usize) -> SkewPlan {
    let mut load = cold.to_vec();
    load.resize(p, 0.0);
    let mut plan = SkewPlan::default();
    for &(h, share) in hot {
        let ideal = share * p as f64;
        let lo_span = (ideal.floor() as usize).clamp(1, p);
        let hi_span = (ideal.ceil() as usize).clamp(1, p);
        let mut best = (f64::INFINITY, 0usize, lo_span);
        for span in lo_span..=hi_span {
            let inc = share / span as f64;
            for start in 0..=(p - span) {
                let window_max =
                    load[start..start + span].iter().fold(0.0f64, |a, &b| a.max(b));
                let resulting = window_max + inc;
                if resulting < best.0 - 1e-12 {
                    best = (resulting, start, span);
                }
            }
        }
        let (_, start, span) = best;
        let inc = share / span as f64;
        for r in start..start + span {
            load[r] += inc;
        }
        plan.hot.insert(h, HotRange { start, span, share });
    }
    plan
}

/// Estimate + hot-key selection + greedy assignment in one call (the
/// single-table path used by the balanced shuffle and groupby).
pub fn plan_for(t: &Table, key_cols: &[usize], env: &CylonEnv) -> Result<SkewPlan> {
    let cfg = env.comm().exchange_config().skew.clone();
    let p = env.world_size();
    let est = estimate_keys(t, key_cols, env)?;
    let hot = est.hot_keys(cfg.hot_key_threshold, p);
    if hot.is_empty() {
        return Ok(SkewPlan::default());
    }
    // Record the detection decision itself, not just its effect: the
    // routing counters land later via `record_skew`, but a timeline
    // reader wants to see *when* the estimator flagged hot keys.
    env.trace().event(
        crate::trace::TraceCat::Skew,
        "skew_detected",
        hot.len() as u64,
        t.num_rows() as u64,
    );
    let hot_set: BTreeSet<i64> = hot.iter().map(|(h, _)| *h).collect();
    let cold = est.cold_shares(&hot_set, p);
    Ok(assign_ranges(&hot, &cold, p))
}

/// Split `t` into `p` parts under `plan`: cold rows go to
/// `hash mod p`, hot rows round-robin across their assigned range.
/// Returns the parts, the per-rank row counts plain hashing *would* have
/// produced (for the before/after balance report) and the number of
/// rerouted rows.
pub fn partition_salted(
    t: &Table,
    key_cols: &[usize],
    plan: &SkewPlan,
    p: usize,
    hasher: &dyn KeyHasher,
) -> Result<(Vec<Table>, Vec<i64>, u64)> {
    let hashes = ops::kernels::row_hashes(t, key_cols, hasher)?;
    let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); p];
    let mut before = vec![0i64; p];
    let mut spin: BTreeMap<i64, usize> = BTreeMap::new();
    let mut rerouted = 0u64;
    for (row, &h) in hashes.iter().enumerate() {
        let plain = (h as u64 % p as u64) as usize;
        before[plain] += 1;
        let dest = match plan.hot.get(&h) {
            Some(r) => {
                let c = spin.entry(h).or_insert(0);
                let d = r.start + *c % r.span;
                *c += 1;
                rerouted += 1;
                d
            }
            None => plain,
        };
        buckets[dest].push(row as u32);
    }
    let parts = buckets.into_iter().map(|b| t.gather(&b)).collect();
    Ok((parts, before, rerouted))
}

/// Join-side partitioner: rows whose key is hot in `salt` round-robin
/// across their range (this side is the salted/probe side for that key);
/// rows hot in `repl` are **replicated** to every rank of the range (this
/// side is the build side for that key — each of the other side's salted
/// rows must find them locally); everything else routes `hash mod p`.
/// `salt` and `repl` must have disjoint key sets.
pub fn partition_salted_replicating(
    t: &Table,
    key_cols: &[usize],
    salt: &SkewPlan,
    repl: &SkewPlan,
    p: usize,
    hasher: &dyn KeyHasher,
) -> Result<(Vec<Table>, Vec<i64>, u64)> {
    let hashes = ops::kernels::row_hashes(t, key_cols, hasher)?;
    let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); p];
    let mut before = vec![0i64; p];
    let mut spin: BTreeMap<i64, usize> = BTreeMap::new();
    let mut rerouted = 0u64;
    for (row, &h) in hashes.iter().enumerate() {
        let plain = (h as u64 % p as u64) as usize;
        before[plain] += 1;
        if let Some(r) = salt.hot.get(&h) {
            let c = spin.entry(h).or_insert(0);
            buckets[r.start + *c % r.span].push(row as u32);
            *c += 1;
            rerouted += 1;
        } else if let Some(r) = repl.hot.get(&h) {
            for d in r.start..r.start + r.span {
                buckets[d].push(row as u32);
            }
            rerouted += r.span as u64;
        } else {
            buckets[plain].push(row as u32);
        }
    }
    let parts = buckets.into_iter().map(|b| t.gather(&b)).collect();
    Ok((parts, before, rerouted))
}

/// Allreduce the per-destination row counts of a (hypothetical) plain
/// routing and the actual skew-aware routing, returning the global
/// max/mean partition row ratios ×1000 (`(before, after)`).
fn global_balance(env: &CylonEnv, before: &[i64], after: &[i64]) -> Result<(u64, u64)> {
    let p = before.len();
    let mut both = Vec::with_capacity(2 * p);
    both.extend_from_slice(before);
    both.extend_from_slice(after);
    let summed = env.comm().allreduce_sum(&both)?;
    Ok((ratio_milli(&summed[..p]), ratio_milli(&summed[p..])))
}

/// Max/mean of a count vector, ×1000; 1000 for an empty/zero vector.
fn ratio_milli(counts: &[i64]) -> u64 {
    let total: i64 = counts.iter().sum();
    if total <= 0 || counts.is_empty() {
        return 1000;
    }
    let mean = total as f64 / counts.len() as f64;
    let max = counts.iter().copied().max().unwrap_or(0) as f64;
    (max / mean * 1000.0).round() as u64
}

/// Hash-repartition with skew handling: like
/// [`crate::dist::shuffle_by_key`], but hot keys are salted across a
/// contiguous rank range by the split-assignment plan, so every rank
/// receives a near-equal share even under a dominant key.
///
/// **Contract change vs the strict shuffle:** when hot keys are detected
/// (and only then), their rows end up split across ranks — callers that
/// need key co-location (a following `*_prepartitioned` call) must use
/// the strict shuffle instead. With skew handling disabled, at `p = 1`,
/// or when nothing is hot, this is exactly the strict shuffle.
pub fn shuffle_by_key_balanced(t: &Table, key_cols: &[usize], env: &CylonEnv) -> Result<Table> {
    check_keys(t, key_cols, "dist::shuffle_by_key_balanced")?;
    let p = env.world_size();
    if p == 1 {
        return Ok(t.clone());
    }
    if !env.comm().exchange_config().skew.enabled {
        return super::shuffle_by_key(t, key_cols, env);
    }
    let plan = plan_for(t, key_cols, env)?;
    if plan.is_empty() {
        return super::shuffle_by_key(t, key_cols, env);
    }
    let (parts, before, rerouted) = env.time(Phase::Auxiliary, || {
        partition_salted(t, key_cols, &plan, p, env.hasher())
    })?;
    let after: Vec<i64> = parts.iter().map(|t| t.num_rows() as i64).collect();
    let (rb, ra) = global_balance(env, &before, &after)?;
    env.record_skew(&SkewStats {
        hot_keys: plan.len() as u64,
        rows_rerouted: rerouted,
        ratio_before_milli: rb,
        ratio_after_milli: ra,
    });
    env.comm().shuffle_streamed(parts)
}

/// Skew-aware distributed join. Result rows are identical (as a global
/// multiset) to [`crate::dist::join()`]; placement is not — hot-key output
/// rows may be split across the key's rank range, so the output carries
/// no hash co-location guarantee (the plan optimizer tracks this as a
/// `balanced` hash partitioning and never elides downstream shuffles).
///
/// Strategy, decided identically on every rank from global estimates:
///
/// 1. **Fallthrough** — skew disabled, `p = 1`, full-outer join, or no
///    hot keys: exactly [`crate::dist::join()`].
/// 2. **Broadcast fallback** — one side's hottest key holds over half
///    that side's rows *and* the other side is small enough that
///    replicating it costs no more than shuffling the big side
///    (`small × p ≤ big`): allgather the small side, keep the big
///    skewed side in place, join locally. Zero bytes of the skewed side
///    cross the wire. (Join type permitting: the kept side must be the
///    row-preserving side of an outer join.)
/// 3. **Salted exchange** — each hot key is salted on its heavier side
///    and replicated on the other (a left/right outer join may only salt
///    its row-preserving side, so null-extension still happens exactly
///    once); cold keys hash as usual; then one local join per rank.
pub fn join_skew(
    left: &Table,
    right: &Table,
    opts: &JoinOptions,
    env: &CylonEnv,
) -> Result<Table> {
    if opts.left_on.is_empty() || opts.left_on.len() != opts.right_on.len() {
        return Err(Error::invalid(
            "dist::join_skew requires equal, non-empty key column lists",
        ));
    }
    let p = env.world_size();
    let cfg = env.comm().exchange_config().skew.clone();
    if !cfg.enabled || p == 1 || opts.join_type == JoinType::FullOuter {
        return super::join_with_exchange(left, right, opts, ExchangeSides::Both, env);
    }
    let lest = estimate_keys(left, &opts.left_on, env)?;
    let rest = estimate_keys(right, &opts.right_on, env)?;
    let hot_l = lest.hot_keys(cfg.hot_key_threshold, p);
    let hot_r = rest.hot_keys(cfg.hot_key_threshold, p);
    let totals = env
        .comm()
        .allreduce_sum(&[left.num_rows() as i64, right.num_rows() as i64])?;
    let (l_tot, r_tot) = (totals[0].max(0) as f64, totals[1].max(0) as f64);

    // --- broadcast-smaller-side fallback --------------------------------
    // Dominance is judged from the *supported* hot-key list (≥ the
    // minimum sample hits), not the raw max share, so a sparse sample
    // cannot trigger an expensive broadcast on uniform data.
    let dom_share = |hot: &[(i64, f64)]| hot.first().map(|(_, s)| *s).unwrap_or(0.0);
    let bcast_right = dom_share(&hot_l) > 0.5
        && r_tot * p as f64 <= l_tot
        && matches!(opts.join_type, JoinType::Inner | JoinType::Left);
    let bcast_left = !bcast_right
        && dom_share(&hot_r) > 0.5
        && l_tot * p as f64 <= r_tot
        && matches!(opts.join_type, JoinType::Inner | JoinType::Right);
    if bcast_right || bcast_left {
        let (dom_hot, bcast_rows) = if bcast_right {
            (&hot_l, right.num_rows())
        } else {
            (&hot_r, left.num_rows())
        };
        env.record_skew(&SkewStats {
            hot_keys: dom_hot.len() as u64,
            rows_rerouted: bcast_rows as u64,
            ratio_before_milli: 0,
            ratio_after_milli: 0,
        });
        return if bcast_right {
            let r_all = env.comm().allgather_streamed(right)?;
            env.time(Phase::Compute, || {
                ops::join_with_pool(left, &r_all, opts, env.hasher(), env.pool())
            })
        } else {
            let l_all = env.comm().allgather_streamed(left)?;
            env.time(Phase::Compute, || {
                ops::join_with_pool(&l_all, right, opts, env.hasher(), env.pool())
            })
        };
    }

    // --- per-key salt-side selection ------------------------------------
    let combined = (l_tot + r_tot).max(1.0);
    // (hash, combined share, salt-on-left) for the shared greedy pass
    let mut entries: Vec<(i64, f64, bool)> = Vec::new();
    let hot_l_set: BTreeSet<i64> = hot_l.iter().map(|(h, _)| *h).collect();
    let hot_r_set: BTreeSet<i64> = hot_r.iter().map(|(h, _)| *h).collect();
    for h in hot_l_set.union(&hot_r_set) {
        let le = lest.share(*h) * l_tot;
        let re = rest.share(*h) * r_tot;
        let salt_left = match opts.join_type {
            // only the row-preserving side may be salted: replicating it
            // would null-extend its unmatched rows once per replica
            JoinType::Left => {
                if !hot_l_set.contains(h) {
                    continue;
                }
                true
            }
            JoinType::Right => {
                if !hot_r_set.contains(h) {
                    continue;
                }
                false
            }
            _ => le >= re,
        };
        entries.push((*h, (le + re) / combined, salt_left));
    }
    if entries.is_empty() {
        return super::join_with_exchange(left, right, opts, ExchangeSides::Both, env);
    }
    entries.sort_by(|a, b| heavier_first((a.0, a.1), (b.0, b.1)));
    // shared cold-load model: both sides' non-treated keys, combined
    let treated: BTreeSet<i64> = entries.iter().map(|(h, _, _)| *h).collect();
    let mut cold = vec![0.0; p];
    for (r, c) in cold.iter_mut().zip(lest.cold_shares(&treated, p)) {
        *r += c * l_tot / combined;
    }
    for (r, c) in cold.iter_mut().zip(rest.cold_shares(&treated, p)) {
        *r += c * r_tot / combined;
    }
    let flat: Vec<(i64, f64)> = entries.iter().map(|(h, s, _)| (*h, *s)).collect();
    let shared = assign_ranges(&flat, &cold, p);
    let mut plan_l = SkewPlan::default();
    let mut plan_r = SkewPlan::default();
    for (h, _, salt_left) in &entries {
        let range = shared.hot[h];
        if *salt_left {
            plan_l.hot.insert(*h, range);
        } else {
            plan_r.hot.insert(*h, range);
        }
    }

    // --- salted exchange + local join -----------------------------------
    let (lparts, lbefore, lrer) = env.time(Phase::Auxiliary, || {
        partition_salted_replicating(left, &opts.left_on, &plan_l, &plan_r, p, env.hasher())
    })?;
    let (rparts, rbefore, rrer) = env.time(Phase::Auxiliary, || {
        partition_salted_replicating(right, &opts.right_on, &plan_r, &plan_l, p, env.hasher())
    })?;
    let before: Vec<i64> = lbefore.iter().zip(&rbefore).map(|(a, b)| a + b).collect();
    let after: Vec<i64> = lparts
        .iter()
        .zip(&rparts)
        .map(|(a, b)| (a.num_rows() + b.num_rows()) as i64)
        .collect();
    let (rb, ra) = global_balance(env, &before, &after)?;
    env.record_skew(&SkewStats {
        hot_keys: entries.len() as u64,
        rows_rerouted: lrer + rrer,
        ratio_before_milli: rb,
        ratio_after_milli: ra,
    });
    let l = env.comm().shuffle_streamed(lparts)?;
    let r = env.comm().shuffle_streamed(rparts)?;
    env.time(Phase::Compute, || {
        ops::join_with_pool(&l, &r, opts, env.hasher(), env.pool())
    })
}

/// Skew-aware distributed sort: identical global order and row multiset
/// as [`crate::dist::sort()`], but hot keys no longer pile into one rank —
/// the splitter derivation keeps duplicate splitters for runs longer
/// than a bucket, and the tie-spreading range partitioner round-robins
/// those rows across the bucket range the duplicates open.
///
/// Falls back to the strict sort when skew handling is disabled, at
/// `p = 1`, or for **stable** sorts (spreading interleaves equal rows
/// from different source ranks, losing their original relative order).
/// After a balanced sort, equal keys may straddle adjacent ranks: rank
/// order still agrees with the sort keys (so a later sort on the *same
/// or fewer* keys can still skip its exchange — never one that extends
/// the key list), but equal-key co-location is gone — both tracked by
/// the optimizer's `balanced` range partitioning.
pub fn sort_balanced(t: &Table, opts: &SortOptions, env: &CylonEnv) -> Result<Table> {
    super::sort::check_sort_keys(t, opts)?;
    let p = env.world_size();
    if p == 1 {
        return env.time(Phase::Compute, || ops::sort_with_pool(t, opts, env.pool()));
    }
    let cfg = env.comm().exchange_config().skew.clone();
    if !cfg.enabled || opts.stable {
        return super::sort(t, opts, env);
    }
    let key_cols: Vec<usize> = opts.keys.iter().map(|k| k.col).collect();
    let dirs: Vec<bool> = opts.keys.iter().map(|k| k.ascending).collect();

    // Oversampled allgather, as in the strict sort (never fewer rows).
    let per_rank = cfg.sample_per_rank.max(32);
    let sample = env.time(Phase::Auxiliary, || {
        ops::sample_rows(t, (per_rank * p).max(64), SAMPLE_SEED ^ env.rank() as u64)
    });
    let global_sample = env.comm().allgather_streamed(&sample)?;

    // Run-aware splitters over the directed order: cuts snap to run
    // boundaries for small runs, stay *inside* hot runs (duplicating the
    // splitter once per bucket-worth of sampled mass).
    let splitters = env.time(Phase::Auxiliary, || -> Result<Table> {
        let idx = ops::sort::sort_indices(&global_sample, opts)?;
        let sorted = global_sample.gather(&idx).project(&key_cols)?;
        balanced_splitters(&sorted, p)
    })?;
    let splitter_cols: Vec<usize> = (0..key_cols.len()).collect();
    let duplicates = duplicate_splitter_groups(&splitters);

    let (mut parts, mut before) = env.time(Phase::Auxiliary, || {
        ops::partition_by_range_directed_spread(t, &key_cols, &splitters, &splitter_cols, &dirs)
    })?;
    while parts.len() < p {
        parts.push(t.slice(0, 0));
    }
    before.resize(p, 0);
    // Balance report: `before` is what the non-spreading router would
    // have done (computed in the same partitioning pass). The allreduce
    // runs unconditionally — rows can tie a *unique* splitter too (tie
    // range width 2), and whether any rank rerouted is not knowable
    // locally, so gating the collective would deadlock the gang.
    let after: Vec<i64> = parts.iter().map(|t| t.num_rows() as i64).collect();
    let rerouted: u64 = parts
        .iter()
        .zip(&before)
        .map(|(a, &b)| (a.num_rows() as i64 - b).unsigned_abs())
        .sum::<u64>()
        / 2;
    let (rb, ra) = global_balance(env, &before, &after)?;
    if duplicates > 0 || rerouted > 0 {
        env.record_skew(&SkewStats {
            hot_keys: duplicates,
            rows_rerouted: rerouted,
            ratio_before_milli: rb,
            ratio_after_milli: ra,
        });
    }
    let mine = env.comm().shuffle_streamed(parts)?;
    env.time(Phase::Compute, || ops::sort_with_pool(&mine, opts, env.pool()))
}

/// Derive `p − 1` splitters from the *sorted, keys-only* global sample,
/// aware of equality runs: the equi-quantile cut positions are kept, but
/// a cut landing in a run no longer than half a bucket is snapped to the
/// run's end (whole small runs stay in one bucket), while cuts inside a
/// longer (hot) run stay put — producing one duplicate splitter per
/// bucket-worth of that run's mass, which is exactly what the
/// tie-spreading partitioner needs to split the run across ranks.
pub fn balanced_splitters(sorted: &Table, p: usize) -> Result<Table> {
    let n = sorted.num_rows();
    if p <= 1 || n == 0 {
        return Ok(sorted.slice(0, 0));
    }
    let all_cols: Vec<usize> = (0..sorted.num_columns()).collect();
    let cols = all_cols.as_slice();
    let eq = |a: usize, b: usize| ops::kernels::rows_equal(sorted, a, cols, sorted, b, cols);
    let small_run = (n / (2 * p)).max(1);
    let mut picks: Vec<u32> = Vec::with_capacity(p - 1);
    for i in 1..p {
        let pos = ((i * n) / p).min(n - 1);
        let mut run_start = pos;
        while run_start > 0 && eq(run_start - 1, pos) {
            run_start -= 1;
        }
        let mut run_end = pos + 1;
        while run_end < n && eq(run_end, pos) {
            run_end += 1;
        }
        let pick = if run_end - run_start <= small_run {
            (run_end - 1) as u32
        } else {
            pos as u32
        };
        picks.push(pick);
    }
    Ok(sorted.gather(&picks))
}

/// Number of splitter values that appear more than once (each duplicate
/// group marks one hot run the spreader will split across ranks).
fn duplicate_splitter_groups(splitters: &Table) -> u64 {
    let n = splitters.num_rows();
    if n < 2 {
        return 0;
    }
    let cols: Vec<usize> = (0..splitters.num_columns()).collect();
    let mut groups = 0;
    let mut i = 0;
    while i < n {
        let mut j = i + 1;
        while j < n && ops::kernels::rows_equal(splitters, i, &cols, splitters, j, &cols) {
            j += 1;
        }
        if j - i > 1 {
            groups += 1;
        }
        i = j;
    }
    groups
}

/// The shuffle-first groupby's skew path (called by
/// [`crate::dist::groupby()`]): salted raw shuffle, then the *rebuild* —
/// cold keys (fully co-located, as in the strict shuffle) aggregate
/// directly; hot keys (split across their rank range) run the two-phase
/// partial/merge machinery, which lands their final group on the owner
/// rank. The concatenated output therefore keeps the strict groupby's
/// co-location contract while the expensive raw-row exchange is
/// balanced.
///
/// Returns `Ok(None)` when skew handling is disabled, at `p = 1`, or
/// when nothing is hot — the caller then runs the plain path. The
/// decision is made from the globally-identical estimate, so all ranks
/// agree.
pub(crate) fn groupby_shuffle_first_balanced(
    t: &Table,
    key_cols: &[usize],
    aggs: &[ops::AggSpec],
    env: &CylonEnv,
) -> Result<Option<Table>> {
    let p = env.world_size();
    if p == 1 || !env.comm().exchange_config().skew.enabled {
        return Ok(None);
    }
    let plan = plan_for(t, key_cols, env)?;
    if plan.is_empty() {
        return Ok(None);
    }
    let (parts, before, rerouted) = env.time(Phase::Auxiliary, || {
        partition_salted(t, key_cols, &plan, p, env.hasher())
    })?;
    let after: Vec<i64> = parts.iter().map(|t| t.num_rows() as i64).collect();
    let (rb, ra) = global_balance(env, &before, &after)?;
    env.record_skew(&SkewStats {
        hot_keys: plan.len() as u64,
        rows_rerouted: rerouted,
        ratio_before_milli: rb,
        ratio_after_milli: ra,
    });
    let mine = env.comm().shuffle_streamed(parts)?;

    // Rebuild: split received rows into cold (complete groups) and hot
    // (partial groups spread over the key's range).
    let (cold_rows, hot_rows) = env.time(Phase::Auxiliary, || -> Result<(Table, Table)> {
        let hashes = ops::kernels::row_hashes(&mine, key_cols, env.hasher())?;
        let mut cold_idx = Vec::new();
        let mut hot_idx = Vec::new();
        for (row, h) in hashes.iter().enumerate() {
            if plan.hot.contains_key(h) {
                hot_idx.push(row as u32);
            } else {
                cold_idx.push(row as u32);
            }
        }
        Ok((mine.gather(&cold_idx), mine.gather(&hot_idx)))
    })?;
    let cold_out = env.time(Phase::Compute, || {
        ops::groupby_with_pool(&cold_rows, key_cols, aggs, env.hasher(), env.pool())
    })?;
    let hot_out = super::groupby::groupby_two_phase(&hot_rows, key_cols, aggs, env)?;
    Ok(Some(Table::concat_owned(vec![cold_out, hot_out])?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::column::Column;
    use crate::ops::NativeHasher;

    #[test]
    fn assign_ranges_spreads_and_packs() {
        // zipf(1.2)-ish shares over 4 keys on 4 ranks, no cold mass
        let hot = vec![(11i64, 0.53), (22, 0.23), (33, 0.14), (44, 0.10)];
        let plan = assign_ranges(&hot, &[0.0; 4], 4);
        let top = plan.hot[&11];
        assert_eq!(top.span, 3, "53% of 4 ranks must span ceil(2.12)=3");
        // simulate the resulting loads
        let mut load = [0.0f64; 4];
        for r in plan.hot.values() {
            for l in load.iter_mut().skip(r.start).take(r.span) {
                *l += r.share / r.span as f64;
            }
        }
        let max = load.iter().fold(0.0f64, |a, &b| a.max(b));
        assert!(max / 0.25 < 1.5, "greedy plan unbalanced: {load:?}");
    }

    #[test]
    fn assign_ranges_respects_cold_load() {
        // rank 0 already holds 30% cold mass: a 20% hot key must avoid it
        let plan = assign_ranges(&[(7, 0.2)], &[0.3, 0.1, 0.1, 0.1], 4);
        let r = plan.hot[&7];
        assert_eq!(r.span, 1);
        assert_ne!(r.start, 0, "greedy must not stack onto the loaded rank");
    }

    #[test]
    fn salted_partition_splits_hot_key_evenly() {
        let mut keys = vec![1i64, 2, 3];
        keys.extend(vec![77i64; 90]);
        let t = Table::from_columns(vec![("k", Column::from_i64(keys))]).unwrap();
        let h = ops::kernels::row_hashes(&t, &[0], &NativeHasher).unwrap();
        let hot_hash = h[3]; // hash of key 77
        let mut plan = SkewPlan::default();
        plan.hot.insert(hot_hash, HotRange { start: 1, span: 3, share: 0.9 });
        let (parts, before, rerouted) =
            partition_salted(&t, &[0], &plan, 4, &NativeHasher).unwrap();
        assert_eq!(rerouted, 90);
        assert_eq!(before.iter().sum::<i64>(), 93);
        // 90 hot rows round-robin over ranks 1..=3 → 30 each
        for r in 1..4 {
            let hot_count = parts[r]
                .column(0)
                .unwrap()
                .i64_values()
                .unwrap()
                .iter()
                .filter(|&&k| k == 77)
                .count();
            assert_eq!(hot_count, 30, "rank {r}");
        }
        assert_eq!(parts.iter().map(|p| p.num_rows()).sum::<usize>(), 93);
    }

    #[test]
    fn replicating_partition_copies_hot_rows_across_range() {
        let t =
            Table::from_columns(vec![("k", Column::from_i64(vec![5, 5, 9]))]).unwrap();
        let h = ops::kernels::row_hashes(&t, &[0], &NativeHasher).unwrap();
        let mut repl = SkewPlan::default();
        repl.hot.insert(h[0], HotRange { start: 0, span: 3, share: 0.5 });
        let (parts, _, rerouted) = partition_salted_replicating(
            &t,
            &[0],
            &SkewPlan::default(),
            &repl,
            4,
            &NativeHasher,
        )
        .unwrap();
        assert_eq!(rerouted, 6, "2 hot rows × span 3");
        for r in 0..3 {
            let fives = parts[r]
                .column(0)
                .unwrap()
                .i64_values()
                .unwrap()
                .iter()
                .filter(|&&k| k == 5)
                .count();
            assert_eq!(fives, 2, "rank {r} must hold both replicas");
        }
        // total = 2 rows × 3 replicas + 1 cold row
        assert_eq!(parts.iter().map(|p| p.num_rows()).sum::<usize>(), 7);
    }

    #[test]
    fn balanced_splitters_duplicate_hot_runs_only() {
        // sorted sample: 10 distinct small keys, then a 60-row hot run
        let mut keys: Vec<i64> = (0..10).collect();
        keys.extend(vec![50i64; 60]);
        let t = Table::from_columns(vec![("k", Column::from_i64(keys))]).unwrap();
        let sp = balanced_splitters(&t, 4).unwrap();
        assert_eq!(sp.num_rows(), 3);
        let vals = sp.column(0).unwrap().i64_values().unwrap();
        // the hot run straddles all equi-quantile cuts except maybe the
        // first → duplicated hot-key splitters appear
        assert!(vals.iter().filter(|&&v| v == 50).count() >= 2, "{vals:?}");
        assert_eq!(duplicate_splitter_groups(&sp), 1);
        // non-skewed sample: all splitters distinct
        let u: Vec<i64> = (0..100).collect();
        let t = Table::from_columns(vec![("k", Column::from_i64(u))]).unwrap();
        let sp = balanced_splitters(&t, 4).unwrap();
        assert_eq!(duplicate_splitter_groups(&sp), 0);
    }

    #[test]
    fn ratio_milli_math() {
        assert_eq!(ratio_milli(&[10, 10, 10, 10]), 1000);
        assert_eq!(ratio_milli(&[40, 0, 0, 0]), 4000);
        assert_eq!(ratio_milli(&[]), 1000);
        assert_eq!(ratio_milli(&[0, 0]), 1000);
    }

    #[test]
    fn estimate_thresholds() {
        let est = KeyEstimate {
            counts: [(1i64, (600.0, 60u64)), (2, (250.0, 25)), (3, (150.0, 2))]
                .into_iter()
                .collect(),
            total: 1000.0,
        };
        // p=4, threshold 0.5 → cut at 12.5%: keys 1 (60%) and 2 (25%)
        // qualify; key 3 (15%) is over the cut but lacks sample support
        let hot = est.hot_keys(0.5, 4);
        assert_eq!(hot.iter().map(|(h, _)| *h).collect::<Vec<_>>(), vec![1, 2]);
        assert!((est.max_share() - 0.6).abs() < 1e-12);
        let cold = est.cold_shares(&[1i64, 2].into_iter().collect(), 4);
        assert!((cold.iter().sum::<f64>() - 0.15).abs() < 1e-12);
    }
}

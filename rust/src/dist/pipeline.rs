//! The paper's Fig 9 composite workload, `join → groupby → sort →
//! add_scalar`, executed as one distributed pipeline with per-stage phase
//! timings (the breakdown the paper's pipeline experiment reports).
//!
//! The stages chain through the partitioning invariants: the join leaves
//! both sides co-partitioned on the key, so the groupby elides its
//! shuffle ([`super::groupby_prepartitioned`]); the sample sort then
//! re-ranges the (much smaller) aggregate table; `add_scalar` is purely
//! local.

use super::{groupby_prepartitioned, join, sort};
use crate::error::Result;
use crate::executor::CylonEnv;
use crate::metrics::{Phase, PhaseTimers};
use crate::ops::{self, AggFun, AggSpec, JoinOptions, SortOptions};
use crate::table::Table;
use std::time::Duration;

/// Phase timers attributed to one pipeline stage (delta of the actor's
/// timers across the stage, communication included).
#[derive(Debug, Clone)]
pub struct StageTiming {
    /// Stage label (`join`, `groupby`, `sort`, `add_scalar`).
    pub name: &'static str,
    /// Compute / auxiliary / communication spent inside the stage.
    pub timers: PhaseTimers,
}

/// Result of [`pipeline`]: this rank's output partition plus the
/// per-stage comm/compute breakdown.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    /// This rank's partition of the final (globally sorted) table.
    pub table: Table,
    /// Per-stage phase timings, in execution order.
    pub stages: Vec<StageTiming>,
}

impl PipelineReport {
    /// Timers summed across all stages.
    pub fn total(&self) -> PhaseTimers {
        let mut t = PhaseTimers::new();
        for s in &self.stages {
            t.merge(&s.timers);
        }
        t
    }

    /// Total communication time across stages.
    pub fn comm_time(&self) -> Duration {
        self.total().get(Phase::Communication)
    }

    /// Total core-compute time across stages.
    pub fn compute_time(&self) -> Duration {
        self.total().get(Phase::Compute)
    }

    /// One-line per-stage report:
    /// `join[compute=… comm=…] groupby[…] sort[…] add_scalar[…]`.
    pub fn report(&self) -> String {
        self.stages
            .iter()
            .map(|s| {
                format!(
                    "{}[compute={:.1}ms aux={:.1}ms comm={:.1}ms]",
                    s.name,
                    s.timers.get(Phase::Compute).as_secs_f64() * 1e3,
                    s.timers.get(Phase::Auxiliary).as_secs_f64() * 1e3,
                    s.timers.get(Phase::Communication).as_secs_f64() * 1e3,
                )
            })
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Run the benchmark pipeline on this rank's partitions:
/// inner-join `left ⋈ right` on column 0, group the result by the key
/// with `sum(col 1)` and `sum(col 3)`, globally sort by the key, then add
/// `scalar` to the first aggregate column. Matches the serial reference
/// `ops::join → ops::groupby → ops::sort → ops::add_scalar` up to row
/// placement.
pub fn pipeline(
    left: &Table,
    right: &Table,
    scalar: f64,
    env: &CylonEnv,
) -> Result<PipelineReport> {
    let mut stages = Vec::with_capacity(4);
    let mut mark = env.metrics_snapshot();

    let joined = join(left, right, &JoinOptions::inner(0, 0), env)?;
    cut(&mut stages, "join", &mut mark, env);

    // join co-partitioned the rows on column 0 — zero-comm groupby
    let grouped = groupby_prepartitioned(
        &joined,
        &[0],
        &[AggSpec::new(1, AggFun::Sum), AggSpec::new(3, AggFun::Sum)],
        env,
    )?;
    cut(&mut stages, "groupby", &mut mark, env);

    let sorted = sort(&grouped, &SortOptions::by(0), env)?;
    cut(&mut stages, "sort", &mut mark, env);

    let table = env.time(Phase::Compute, || ops::add_scalar(&sorted, 1, scalar))?;
    cut(&mut stages, "add_scalar", &mut mark, env);

    Ok(PipelineReport { table, stages })
}

/// Close a stage: attribute the timer delta since `mark` to `name`.
fn cut(stages: &mut Vec<StageTiming>, name: &'static str, mark: &mut PhaseTimers, env: &CylonEnv) {
    let now = env.metrics_snapshot();
    stages.push(StageTiming {
        name,
        timers: now.saturating_diff(mark),
    });
    *mark = now;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen;
    use crate::executor::{Cluster, CylonExecutor};

    #[test]
    fn report_has_nonzero_comm_and_compute_phases() {
        let p = 2;
        let c = Cluster::local(p).unwrap();
        let exec = CylonExecutor::new(&c, p).unwrap();
        let out = exec
            .run(|env| {
                let l = datagen::partition_for_rank(801, 4000, 0.9, env.rank(), env.world_size());
                let r = datagen::partition_for_rank(802, 4000, 0.9, env.rank(), env.world_size());
                pipeline(&l, &r, 1.5, env)
            })
            .unwrap()
            .wait()
            .unwrap();
        for rep in &out {
            assert_eq!(rep.stages.len(), 4);
            assert!(rep.comm_time() > Duration::ZERO, "no comm recorded");
            assert!(rep.compute_time() > Duration::ZERO, "no compute recorded");
            assert!(rep.report().contains("join["));
        }
    }

    #[test]
    fn matches_composed_local_reference() {
        let p = 3;
        let c = Cluster::local(p).unwrap();
        let exec = CylonExecutor::new(&c, p).unwrap();
        let out = exec
            .run(|env| {
                let l = datagen::partition_for_rank(803, 3000, 0.9, env.rank(), env.world_size());
                let r = datagen::partition_for_rank(804, 3000, 0.9, env.rank(), env.world_size());
                pipeline(&l, &r, 5.0, env).map(|rep| rep.table)
            })
            .unwrap()
            .wait()
            .unwrap();
        let whole = |seed: u64| {
            let parts: Vec<Table> = (0..p)
                .map(|r| datagen::partition_for_rank(seed, 3000, 0.9, r, p))
                .collect();
            Table::concat(&parts.iter().collect::<Vec<_>>()).unwrap()
        };
        let j = ops::join(&whole(803), &whole(804), &JoinOptions::inner(0, 0)).unwrap();
        let g = ops::groupby(
            &j,
            &[0],
            &[AggSpec::new(1, AggFun::Sum), AggSpec::new(3, AggFun::Sum)],
        )
        .unwrap();
        let s = ops::sort(&g, &SortOptions::by(0)).unwrap();
        let reference = ops::add_scalar(&s, 1, 5.0).unwrap();
        let all = Table::concat(&out.iter().collect::<Vec<_>>()).unwrap();
        assert_eq!(all.num_rows(), reference.num_rows());
        // globally sorted: the rank-ordered concatenation is ordered
        assert!(ops::sort::is_sorted(&all, &SortOptions::by(0)));
    }
}

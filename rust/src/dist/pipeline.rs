//! The paper's Fig 9 composite workload, `join → groupby → sort →
//! add_scalar`, expressed against the lazy planner
//! ([`crate::plan::DistFrame`]) and executed as one distributed pipeline
//! with per-stage phase timings (the breakdown the paper's pipeline
//! experiment reports).
//!
//! This used to hand-chain the partitioning invariants (calling
//! [`super::groupby_prepartitioned`] because the join had co-partitioned
//! the rows); it is now a thin wrapper over the plan optimizer, whose
//! partitioning-lineage pass derives the same shuffle elision
//! automatically — asserted by `elides_groupby_shuffle_automatically`
//! below.

use crate::error::Result;
use crate::executor::CylonEnv;
use crate::ops::{AggFun, AggSpec, JoinOptions, SortOptions};
use crate::plan::DistFrame;
use crate::table::Table;

// Re-exported here for continuity: earlier revisions defined these types
// in this module; they now live with the planner/metrics.
pub use crate::metrics::StageTiming;
pub use crate::plan::PlanReport as PipelineReport;

/// Run the benchmark pipeline on this rank's partitions:
/// inner-join `left ⋈ right` on column 0, group the result by the key
/// with `sum(col 1)` and `sum(col 3)`, globally sort by the key, then add
/// `scalar` to the first aggregate column. Matches the serial reference
/// `ops::join → ops::groupby → ops::sort → ops::add_scalar` up to row
/// placement. Takes the partitions by value — they are consumed by the
/// plan's scan leaves without a copy.
pub fn pipeline(
    left: Table,
    right: Table,
    scalar: f64,
    env: &CylonEnv,
) -> Result<PipelineReport> {
    frame(left, right, scalar).execute(env)
}

/// The Fig 9 workload as a lazy frame (shared with the
/// `plan_pipeline` example, which EXPLAINs it before running).
pub fn frame(left: Table, right: Table, scalar: f64) -> DistFrame {
    DistFrame::scan_named("left", left)
        .join(DistFrame::scan_named("right", right), JoinOptions::inner(0, 0))
        .groupby(&[0], &[AggSpec::new(1, AggFun::Sum), AggSpec::new(3, AggFun::Sum)])
        .sort(SortOptions::by(0))
        .add_scalar(1, scalar)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen;
    use crate::executor::{Cluster, CylonExecutor};
    use crate::ops;
    use crate::plan::{GroupbyMode, PhysNode};
    use std::time::Duration;

    #[test]
    fn elides_groupby_shuffle_automatically() {
        // The acceptance criterion: no hand-written
        // `groupby_prepartitioned` call remains here — the optimizer must
        // derive the elision from the join's partitioning lineage.
        let l = datagen::uniform_table(1, 10, 0.9);
        let r = datagen::uniform_table(2, 10, 0.9);
        let plan = frame(l, r, 1.0).optimized();
        // plan shape: add_scalar → sort → groupby → join
        let sort = match &plan.node {
            PhysNode::AddScalar { input, .. } => input,
            other => panic!("expected AddScalar root, got {other:?}"),
        };
        let groupby = match &sort.node {
            PhysNode::Sort { input, .. } => input,
            other => panic!("expected Sort, got {other:?}"),
        };
        match &groupby.node {
            PhysNode::GroupBy { mode, .. } => {
                assert_eq!(*mode, GroupbyMode::Prepartitioned, "groupby shuffle not elided")
            }
            other => panic!("expected GroupBy, got {other:?}"),
        }
        // join's 2 shuffles + sort's exchange; groupby contributes none
        assert_eq!(plan.exchange_count(), 3);
    }

    #[test]
    fn report_has_nonzero_comm_and_compute_phases() {
        let p = 2;
        let c = Cluster::local(p).unwrap();
        let exec = CylonExecutor::new(&c, p).unwrap();
        let out = exec
            .run(|env| {
                let l = datagen::partition_for_rank(801, 4000, 0.9, env.rank(), env.world_size());
                let r = datagen::partition_for_rank(802, 4000, 0.9, env.rank(), env.world_size());
                pipeline(l, r, 1.5, env)
            })
            .unwrap()
            .wait()
            .unwrap();
        for rep in &out {
            assert_eq!(rep.stages.len(), 4);
            assert!(rep.comm_time() > Duration::ZERO, "no comm recorded");
            assert!(rep.compute_time() > Duration::ZERO, "no compute recorded");
            assert!(rep.report().contains("join["));
        }
    }

    #[test]
    fn matches_composed_local_reference() {
        let p = 3;
        let c = Cluster::local(p).unwrap();
        let exec = CylonExecutor::new(&c, p).unwrap();
        let out = exec
            .run(|env| {
                let l = datagen::partition_for_rank(803, 3000, 0.9, env.rank(), env.world_size());
                let r = datagen::partition_for_rank(804, 3000, 0.9, env.rank(), env.world_size());
                pipeline(l, r, 5.0, env).map(|rep| rep.table)
            })
            .unwrap()
            .wait()
            .unwrap();
        let whole = |seed: u64| {
            let parts: Vec<Table> = (0..p)
                .map(|r| datagen::partition_for_rank(seed, 3000, 0.9, r, p))
                .collect();
            Table::concat_owned(parts).unwrap()
        };
        let j = ops::join(&whole(803), &whole(804), &JoinOptions::inner(0, 0)).unwrap();
        let g = ops::groupby(
            &j,
            &[0],
            &[AggSpec::new(1, AggFun::Sum), AggSpec::new(3, AggFun::Sum)],
        )
        .unwrap();
        let s = ops::sort(&g, &SortOptions::by(0)).unwrap();
        let reference = ops::add_scalar(&s, 1, 5.0).unwrap();
        let all = Table::concat_owned(out).unwrap();
        assert_eq!(all.num_rows(), reference.num_rows());
        // globally sorted: the rank-ordered concatenation is ordered
        assert!(ops::sort::is_sorted(&all, &SortOptions::by(0)));
    }
}

//! PJRT kernel-path benches: per-row cost of the AOT Pallas hash through
//! PJRT vs the native Rust path, plus the L2 graphs — quantifies the
//! PJRT call overhead the Auto hash path weighs (DESIGN.md §Perf).

use cylonflow::bench_util::bench;
use cylonflow::config::default_artifacts_dir;
use cylonflow::ops::{KeyHasher, NativeHasher};
use cylonflow::runtime::{artifacts_present, Kernels, KERNEL_BLOCK};
use cylonflow::util::SplitMix64;

fn main() {
    let dir = default_artifacts_dir();
    if !artifacts_present(&dir) {
        println!("artifacts not built — run `make artifacts` first; skipping PJRT benches");
        return;
    }
    let mut rng = SplitMix64::new(7);
    for blocks in [1usize, 8] {
        let n = blocks * KERNEL_BLOCK;
        let keys: Vec<i64> = (0..n).map(|_| rng.next_i64()).collect();
        let mut out = vec![0i64; n];
        println!("--- hash64 over {n} keys ({blocks} blocks) ---");
        let m = bench(&format!("hash_native/{n}"), 2, 10, || {
            NativeHasher.hash_i64(&keys, &mut out).unwrap();
        });
        println!("{}  ({:.1} ns/row)", m.report(), m.median().as_nanos() as f64 / n as f64);
        let m = bench(&format!("hash_pjrt/{n}"), 2, 10, || {
            Kernels::with(&dir, |k| k.hash64(&keys, &mut out)).unwrap();
        });
        println!("{}  ({:.1} ns/row)", m.report(), m.median().as_nanos() as f64 / n as f64);
    }

    let xs: Vec<f64> = (0..KERNEL_BLOCK).map(|_| rng.next_f64()).collect();
    let mut outf = vec![0f64; xs.len()];
    let m = bench("add_scalar_pjrt/1block", 2, 10, || {
        Kernels::with(&dir, |k| k.add_scalar_f64(&xs, 1.5, &mut outf)).unwrap();
    });
    println!("{}", m.report());
    let m = bench("colagg_pjrt/1block", 2, 10, || {
        Kernels::with(&dir, |k| k.colagg_f64(&xs)).unwrap();
    });
    println!("{}", m.report());
    let keys: Vec<i64> = (0..KERNEL_BLOCK).map(|_| rng.next_i64()).collect();
    let m = bench("partition_hist_pjrt/1block", 2, 10, || {
        Kernels::with(&dir, |k| k.partition_hist(&keys)).unwrap();
    });
    println!("{}", m.report());
}

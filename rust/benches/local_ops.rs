//! Micro-benchmarks: local operator kernels (the "core local operator"
//! costs under every distributed op). Run with `cargo bench`.

use cylonflow::bench_util::bench;
use cylonflow::datagen;
use cylonflow::ops::{self, AggFun, AggSpec, JoinOptions, NativeHasher, SortOptions};
use cylonflow::table::{table_from_bytes, table_to_bytes};

fn main() {
    let sizes = [100_000usize, 1_000_000];
    for &n in &sizes {
        let l = datagen::uniform_table(1, n, 0.9);
        let r = datagen::uniform_table(2, n, 0.9);
        println!("--- local ops, {n} rows, 90% cardinality ---");
        let m = bench(&format!("hash_join/{n}"), 1, 5, || {
            ops::join(&l, &r, &JoinOptions::inner(0, 0)).unwrap();
        });
        println!("{}", m.report());
        let m = bench(&format!("sort_merge_join/{n}"), 1, 3, || {
            ops::join(
                &l,
                &r,
                &JoinOptions::inner(0, 0).with_algo(ops::JoinAlgo::SortMerge),
            )
            .unwrap();
        });
        println!("{}", m.report());
        let m = bench(&format!("groupby_sum/{n}"), 1, 5, || {
            ops::groupby(&l, &[0], &[AggSpec::new(1, AggFun::Sum)]).unwrap();
        });
        println!("{}", m.report());
        let m = bench(&format!("sort/{n}"), 1, 5, || {
            ops::sort(&l, &SortOptions::by(0)).unwrap();
        });
        println!("{}", m.report());
        let m = bench(&format!("partition_by_hash_8/{n}"), 1, 5, || {
            ops::partition_by_hash(&l, &[0], 8, &NativeHasher).unwrap();
        });
        println!("{}", m.report());
        let m = bench(&format!("add_scalar/{n}"), 1, 10, || {
            ops::add_scalar(&l, 1, 1.5).unwrap();
        });
        println!("{}", m.report());
        let bytes = table_to_bytes(&l);
        let m = bench(&format!("wire_serialize/{n}"), 1, 10, || {
            let _ = table_to_bytes(&l);
        });
        println!("{}", m.report());
        let m = bench(&format!("wire_deserialize/{n}"), 1, 10, || {
            let _ = table_from_bytes(&bytes).unwrap();
        });
        println!(
            "{}   ({} MiB wire size)",
            m.report(),
            bytes.len() / (1024 * 1024)
        );
    }
}

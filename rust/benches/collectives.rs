//! Micro-benchmarks: collective algorithms across backends and payload
//! sizes — the paper's §V-B point that algorithm selection (Bruck vs
//! pairwise vs linear) dominates at small payloads while transport
//! dominates at large.

use cylonflow::bench_util::bench;
use cylonflow::comm::algorithms::AllToAllAlgo;
use cylonflow::comm::{AlgoSet, CommContext, InMemoryKv, MemoryFabric, TcpFabric};
use cylonflow::datagen;
use cylonflow::table::Table;
use std::sync::Arc;

fn gang_memory(p: usize, algos: AlgoSet) -> Vec<CommContext> {
    MemoryFabric::create(p)
        .into_iter()
        .map(|c| CommContext::new(Box::new(c), algos))
        .collect()
}

fn gang_tcp(p: usize, algos: AlgoSet, name: &str) -> Vec<CommContext> {
    TcpFabric::create(p, InMemoryKv::shared(), name)
        .unwrap()
        .into_iter()
        .map(|c| CommContext::new(Box::new(c), algos))
        .collect()
}

/// One timed shuffle across a gang (all ranks run in threads; returns when
/// every rank completes — BSP semantics).
fn timed_shuffle(ctxs: &[CommContext], rows_per_part: usize) {
    std::thread::scope(|s| {
        for ctx in ctxs {
            s.spawn(move || {
                let parts: Vec<Table> = (0..ctx.world_size())
                    .map(|j| datagen::uniform_table(j as u64, rows_per_part, 0.9))
                    .collect();
                ctx.shuffle(parts).unwrap();
            });
        }
    });
}

fn main() {
    let p = 4;
    for rows in [100usize, 10_000, 200_000] {
        println!("--- all-to-all shuffle, p={p}, {rows} rows/part ---");
        for (label, algo) in [
            ("linear", AllToAllAlgo::Linear),
            ("pairwise", AllToAllAlgo::Pairwise),
            ("bruck", AllToAllAlgo::Bruck),
        ] {
            let mut algos = AlgoSet::simple();
            algos.all_to_all = algo;
            let ctxs = gang_memory(p, algos);
            let m = bench(&format!("memory/{label}/{rows}"), 1, 5, || {
                timed_shuffle(&ctxs, rows);
            });
            println!("{}", m.report());
        }
        for (label, algos) in [("gloo-ish", AlgoSet::simple()), ("ucc-ish", AlgoSet::optimized())]
        {
            let ctxs = gang_tcp(p, algos, &format!("bench-{label}-{rows}"));
            let m = bench(&format!("tcp/{label}/{rows}"), 1, 5, || {
                timed_shuffle(&ctxs, rows);
            });
            println!("{}", m.report());
        }
    }

    println!("--- allgather / bcast, p={p}, 50k rows ---");
    for (label, algos) in [("simple", AlgoSet::simple()), ("optimized", AlgoSet::optimized())] {
        let ctxs = gang_memory(p, algos);
        let m = bench(&format!("allgather/{label}"), 1, 5, || {
            std::thread::scope(|s| {
                for ctx in &ctxs {
                    s.spawn(move || {
                        let t = datagen::uniform_table(ctx.rank() as u64, 50_000, 0.9);
                        ctx.allgather(&t).unwrap();
                    });
                }
            });
        });
        println!("{}", m.report());
        let m = bench(&format!("bcast/{label}"), 1, 5, || {
            std::thread::scope(|s| {
                for ctx in &ctxs {
                    s.spawn(move || {
                        let t = (ctx.rank() == 0)
                            .then(|| datagen::uniform_table(9, 50_000, 0.9));
                        ctx.bcast(t.as_ref(), 0).unwrap();
                    });
                }
            });
        });
        println!("{}", m.report());
    }
}

//! End-to-end distributed operator benches at fixed parallelism — the
//! `cargo bench` counterpart of the paper's Fig 8 single points (the full
//! sweeps live in `bench_driver`).

use cylonflow::bench_util::bench;
use cylonflow::comm::CommBackend;
use cylonflow::config::Config;
use cylonflow::prelude::*;

fn main() {
    let p = 4;
    let rows = 1 << 19;
    for backend in [CommBackend::Memory, CommBackend::Tcp, CommBackend::TcpUcc] {
        let cfg = Config { backend, ..Config::from_env() };
        let cluster = Cluster::with_config(p, cfg).unwrap();
        let exec = CylonExecutor::new(&cluster, p).unwrap();
        println!("--- dist ops, p={p}, {rows} rows, {} ---", backend.label());
        let m = bench(&format!("dist_join/{}", backend.label()), 1, 3, || {
            exec.run(move |env| {
                let l = datagen::partition_for_rank(1, rows, 0.9, env.rank(), env.world_size());
                let r = datagen::partition_for_rank(2, rows, 0.9, env.rank(), env.world_size());
                dist::join(&l, &r, &JoinOptions::inner(0, 0), env).map(|t| t.num_rows())
            })
            .unwrap()
            .wait()
            .unwrap();
        });
        println!("{}", m.report());
        let m = bench(&format!("dist_groupby/{}", backend.label()), 1, 3, || {
            exec.run(move |env| {
                let t = datagen::partition_for_rank(3, rows, 0.9, env.rank(), env.world_size());
                dist::groupby(
                    &t,
                    &[0],
                    &[AggSpec::new(1, dist::AggFun::Sum)],
                    dist::GroupbyStrategy::ShuffleFirst,
                    env,
                )
                .map(|t| t.num_rows())
            })
            .unwrap()
            .wait()
            .unwrap();
        });
        println!("{}", m.report());
        let m = bench(&format!("dist_sort/{}", backend.label()), 1, 3, || {
            exec.run(move |env| {
                let t = datagen::partition_for_rank(4, rows, 0.9, env.rank(), env.world_size());
                dist::sort(&t, &SortOptions::by(0), env).map(|t| t.num_rows())
            })
            .unwrap()
            .wait()
            .unwrap();
        });
        println!("{}", m.report());
        let m = bench(&format!("dist_pipeline/{}", backend.label()), 1, 3, || {
            exec.run(move |env| {
                let l = datagen::partition_for_rank(5, rows, 0.9, env.rank(), env.world_size());
                let r = datagen::partition_for_rank(6, rows, 0.9, env.rank(), env.world_size());
                dist::pipeline(l, r, 1.0, env).map(|rep| rep.table.num_rows())
            })
            .unwrap()
            .wait()
            .unwrap();
        });
        println!("{}", m.report());
    }
}

//! Interactive session against a *running* cluster — the paper's §IV-D-3
//! point: CylonFlow lets you submit distributed dataframe programs to a
//! live resource pool interactively (Jupyter-style), which bare MPI
//! cannot do. Type small commands; each runs as a fresh SPMD app on the
//! same resident actor gang (communication context reused across
//! commands — no re-initialization).
//!
//! ```bash
//! cargo run --release --example interactive
//! # or non-interactively:
//! echo -e "gen a 100000\ngen b 100000\njoin a b\nsort a\nquit" | \
//!     cargo run --release --example interactive
//! ```

use cylonflow::prelude::*;
use std::io::{BufRead, Write};
use std::time::Duration;

const HELP: &str = "\
commands:
  gen <name> <rows>   generate DDF (2 int64 cols, 90% cardinality)
  join <a> <b>        distributed join on k; stores result as <a>_<b>
  groupby <a>         distributed groupby k, sum(v)
  sort <a>            distributed sort by k
  rows <a>            total rows of a stored DDF
  help | quit";

fn main() -> Result<()> {
    let p = 4;
    let cluster = Cluster::local(p)?;
    let exec = CylonExecutor::new(&cluster, p)?;
    println!("cylonflow interactive — {p} resident actors (type 'help')");

    let stdin = std::io::stdin();
    loop {
        print!("> ");
        std::io::stdout().flush().ok();
        let mut line = String::new();
        if stdin.lock().read_line(&mut line).unwrap_or(0) == 0 {
            break;
        }
        let words: Vec<String> = line.split_whitespace().map(str::to_string).collect();
        let t0 = std::time::Instant::now();
        let result: Result<String> = match words.first().map(|s| s.as_str()) {
            None => continue,
            Some("quit") | Some("exit") => break,
            Some("help") => {
                println!("{HELP}");
                continue;
            }
            Some("gen") if words.len() == 3 => {
                let name = words[1].clone();
                let rows: usize = words[2].parse().unwrap_or(10_000);
                let seed = name.bytes().map(|b| b as u64).sum::<u64>();
                exec.run(move |env| {
                    let t = datagen::partition_for_rank(
                        seed, rows, 0.9, env.rank(), env.world_size());
                    env.store().put(&name, t)
                })?
                .wait()
                .map(|_| format!("generated '{}' ({rows} rows)", words[1]))
            }
            Some("join") if words.len() == 3 => {
                let (a, b) = (words[1].clone(), words[2].clone());
                let out_name = format!("{a}_{b}");
                let on = out_name.clone();
                exec.run(move |env| {
                    let l = env.store().get(&a, Duration::from_secs(5))?;
                    let r = env.store().get(&b, Duration::from_secs(5))?;
                    let j = dist::join(&l, &r, &JoinOptions::inner(0, 0), env)?;
                    let n = j.num_rows();
                    env.store().put(&on, j)?;
                    Ok(n)
                })?
                .wait()
                .map(|ns| format!("join -> '{out_name}' ({} rows)", ns.iter().sum::<usize>()))
            }
            Some("groupby") if words.len() == 2 => {
                let a = words[1].clone();
                exec.run(move |env| {
                    let t = env.store().get(&a, Duration::from_secs(5))?;
                    let g = dist::groupby(
                        &t,
                        &[0],
                        &[AggSpec::new(1, dist::AggFun::Sum)],
                        dist::GroupbyStrategy::default(),
                        env,
                    )?;
                    Ok(g.num_rows())
                })?
                .wait()
                .map(|ns| format!("groupby -> {} groups", ns.iter().sum::<usize>()))
            }
            Some("sort") if words.len() == 2 => {
                let a = words[1].clone();
                exec.run(move |env| {
                    let t = env.store().get(&a, Duration::from_secs(5))?;
                    let s = dist::sort(&t, &SortOptions::by(0), env)?;
                    Ok(s.num_rows())
                })?
                .wait()
                .map(|ns| format!("sorted {} rows (global order)", ns.iter().sum::<usize>()))
            }
            Some("rows") if words.len() == 2 => {
                let a = words[1].clone();
                exec.run(move |env| {
                    let t = env.store().get(&a, Duration::from_secs(5))?;
                    Ok(t.num_rows())
                })?
                .wait()
                .map(|ns| format!("{} rows", ns.iter().sum::<usize>()))
            }
            Some(other) => {
                println!("unknown command '{other}' (try 'help')");
                continue;
            }
        };
        match result {
            Ok(msg) => println!("{msg}   [{:.3}s]", t0.elapsed().as_secs_f64()),
            Err(e) => println!("error: {e}"),
        }
    }
    // Exit report: one unified metrics line for the whole session
    // (rank 0's view — phases, spill, skew, overlap, counters).
    if let Some(snap) = exec.run(|env| Ok(env.snapshot()))?.wait()?.into_iter().next() {
        println!("{}", snap.summary());
    }
    println!("bye");
    Ok(())
}

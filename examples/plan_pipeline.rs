//! The paper's Fig 9 workload (`join → groupby → sort → add_scalar`)
//! written against the lazy planner: build a `DistFrame`, EXPLAIN the
//! optimized plan (showing the shuffle the partitioning-lineage pass
//! elides), execute it, and report the per-stage comm/compute breakdown
//! (including exchange spill) against the unoptimized plan.
//!
//! ```bash
//! cargo run --release --example plan_pipeline -- [rows] [workers]
//! ```
//!
//! The exchanges stream through the out-of-core path: received shuffle
//! frames beyond the spill budget wait on disk instead of aborting the
//! run. Knobs (see `config::ExchangeConfig`):
//!
//! - `CYLONFLOW_SPILL_BUDGET` — in-memory bytes per exchange before
//!   spilling (suffix `k`/`m`/`g` allowed; default 256m). Set it to a
//!   few `k` to watch the `spill` column light up at any data size:
//!   `CYLONFLOW_SPILL_BUDGET=8k cargo run --release --example
//!   plan_pipeline -- 200000 4`
//! - `CYLONFLOW_FRAME_BYTES` — wire-frame payload target (default 4m).
//! - `CYLONFLOW_SPILL_DIR` — temp-file directory (default: the system
//!   temp dir; files are created only on overflow and removed after the
//!   exchange merges).
//! - `CYLONFLOW_OVERLAP=1` — route the shuffles through the nonblocking
//!   double-buffered path (DESIGN.md §9): chunk k+1 encodes while chunk
//!   k is on the wire; the overlap summary line lights up.
//!   `CYLONFLOW_INFLIGHT_CHUNKS` sets the per-peer depth (default 2).
//! - `CYLONFLOW_TRACE=1` — record a per-rank event trace of the
//!   optimized run (stage spans, collective spans, spill and skew
//!   events) and export the merged cross-rank timeline to
//!   `plan_pipeline.trace.json`, loadable at `chrome://tracing` or
//!   <https://ui.perfetto.dev> (DESIGN.md §10).

use cylonflow::dist::pipeline::frame;
use cylonflow::metrics::Phase;
use cylonflow::plan::PlanReport;
use cylonflow::prelude::*;
use std::time::Instant;

/// Human-readable byte count for the spill column.
fn fmt_bytes(b: u64) -> String {
    match b {
        0..=1023 => format!("{b}B"),
        1024..=1048575 => format!("{:.1}KiB", b as f64 / 1024.0),
        _ => format!("{:.1}MiB", b as f64 / (1024.0 * 1024.0)),
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let rows: usize = argv.first().and_then(|v| v.parse().ok()).unwrap_or(500_000);
    let p: usize = argv.get(1).and_then(|v| v.parse().ok()).unwrap_or(4);
    let card = 0.9;
    println!("plan pipeline: join → groupby → sort → add_scalar");
    println!("rows={rows} x2 tables, cardinality={card}, parallelism={p}\n");

    // EXPLAIN from the driver: the optimizer only reads plan shape, so
    // zero-row tables with the right schema suffice.
    let probe = || datagen::uniform_table(0, 0, card);
    let lazy = frame(probe(), probe(), 42.0);
    println!("=== logical plan ===\n{}", lazy.plan());
    let optimized = lazy.optimized();
    let unoptimized = cylonflow::plan::unoptimized(lazy.plan().clone());
    println!("=== optimized plan (EXPLAIN) ===\n{optimized}");
    println!(
        "exchanges: {} optimized vs {} unoptimized — the groupby shuffle \
         is elided from the join's partitioning lineage\n",
        optimized.exchange_count(),
        unoptimized.exchange_count()
    );

    // Execute both plans on the gang and compare.
    let cluster = Cluster::local(p)?;
    let exec = CylonExecutor::new(&cluster, p)?;
    let run = |optimize: bool| -> Result<(Vec<PlanReport>, f64)> {
        let t0 = Instant::now();
        let reports = exec
            .run(move |env| {
                let l = datagen::partition_for_rank(101, rows, card, env.rank(), env.world_size());
                let r = datagen::partition_for_rank(102, rows, card, env.rank(), env.world_size());
                env.barrier()?; // exclude generation skew from the timing
                let f = frame(l, r, 42.0);
                if optimize {
                    f.execute(env)
                } else {
                    f.execute_unoptimized(env)
                }
            })?
            .wait()?;
        Ok((reports, t0.elapsed().as_secs_f64()))
    };

    let (opt_reports, opt_time) = run(true)?;

    // With CYLONFLOW_TRACE=1: gather every rank's event buffer, align
    // clocks, and export the merged timeline of the optimized run
    // (before the unoptimized pass muddies the buffers).
    let timelines = exec.run(|env| env.trace_snapshot())?.wait()?;
    if let Some(timeline) = timelines.into_iter().next().flatten() {
        let out = "plan_pipeline.trace.json";
        std::fs::write(out, cylonflow::trace::chrome::chrome_trace_json(&timeline))?;
        println!("{}", cylonflow::trace::chrome::text_summary(&timeline));
        println!("wrote {out} ({} events) — open in chrome://tracing\n", timeline.events.len());
    }

    let (naive_reports, naive_time) = run(false)?;

    let out_rows: usize = opt_reports.iter().map(|r| r.table.num_rows()).sum();
    println!("=== per-stage breakdown (rank 0, optimized) ===");
    for s in &opt_reports[0].stages {
        println!(
            "  {:<10} compute={:>7.1}ms aux={:>7.1}ms comm={:>7.1}ms spill={:>6}",
            s.name,
            s.timers.get(Phase::Compute).as_secs_f64() * 1e3,
            s.timers.get(Phase::Auxiliary).as_secs_f64() * 1e3,
            s.timers.get(Phase::Communication).as_secs_f64() * 1e3,
            fmt_bytes(s.spill.spilled_bytes),
        );
    }
    let spill_total: u64 = opt_reports.iter().map(|r| r.spill().spilled_bytes).sum();
    println!(
        "exchange spill across ranks: {} ({})",
        fmt_bytes(spill_total),
        if spill_total == 0 {
            "all exchanges fit the in-memory budget; try CYLONFLOW_SPILL_BUDGET=8k"
        } else {
            "out-of-core path engaged"
        }
    );
    let overlap_total = opt_reports.iter().fold(
        cylonflow::metrics::OverlapStats::default(),
        |mut acc, r| {
            acc.merge(&r.overlap());
            acc
        },
    );
    if overlap_total.is_zero() {
        println!(
            "exchange overlap: off (set CYLONFLOW_OVERLAP=1 to double-buffer the shuffles)"
        );
    } else {
        println!(
            "exchange overlap: {} chunks, {:.1}ms of compute hidden under the wire, \
             {:.1}ms of wire waits remaining",
            overlap_total.chunks_overlapped,
            overlap_total.hidden_nanos as f64 / 1e6,
            overlap_total.wire_wait_nanos as f64 / 1e6,
        );
    }

    let comm = |reports: &[PlanReport]| -> f64 {
        reports
            .iter()
            .map(|r| r.comm_time().as_secs_f64())
            .fold(0.0, f64::max)
    };
    println!("\n=== optimized vs unoptimized ===");
    println!(
        "optimized  : {opt_time:>7.3}s wall, max-rank comm {:>7.1}ms ({out_rows} output rows)",
        comm(&opt_reports) * 1e3
    );
    println!(
        "unoptimized: {naive_time:>7.3}s wall, max-rank comm {:>7.1}ms ({} output rows)",
        comm(&naive_reports) * 1e3,
        naive_reports.iter().map(|r| r.table.num_rows()).sum::<usize>()
    );
    assert_eq!(
        out_rows,
        naive_reports.iter().map(|r| r.table.num_rows()).sum::<usize>(),
        "optimized and unoptimized plans must agree"
    );
    Ok(())
}

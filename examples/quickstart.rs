//! Quickstart: spin up a local cluster, run a distributed join + groupby
//! from the actor API, print the result.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use cylonflow::prelude::*;

fn main() -> Result<()> {
    // A "Dask/Ray cluster": 4 long-lived workers in this process.
    let cluster = Cluster::local(4)?;

    // Gang-reserve all 4 workers and boot the stateful actors (each holds
    // a live communication context — the paper's Cylon_env).
    let exec = CylonExecutor::new(&cluster, 4)?;

    // SPMD application: every actor owns one partition.
    let (results, breakdown) = exec
        .run(|env| {
            // Each worker "loads" its partition (generation stands in for
            // reading Parquet shards).
            let orders =
                datagen::partition_for_rank(1, 100_000, 0.9, env.rank(), env.world_size());
            let customers =
                datagen::partition_for_rank(2, 100_000, 0.9, env.rank(), env.world_size());

            // Distributed join on the key column, then aggregate — the
            // groupby reuses the join's partitioning (zero communication).
            let joined = dist::join(&orders, &customers, &JoinOptions::inner(0, 0), env)?;
            let stats = dist::groupby_prepartitioned(
                &joined,
                &[0],
                &[
                    AggSpec::new(1, dist::AggFun::Sum),
                    AggSpec::new(1, dist::AggFun::Count),
                ],
                env,
            )?;
            let sample = stats.slice(0, stats.num_rows().min(3));
            Ok((joined.num_rows(), stats.num_rows(), sample))
        })?
        .wait_with_metrics()?;

    let joined: usize = results.iter().map(|(j, _, _)| j).sum();
    let groups: usize = results.iter().map(|(_, g, _)| g).sum();
    println!("distributed join produced {joined} rows, {groups} groups\n");
    println!("sample of rank 0's group partition:\n{}", results[0].2);
    println!("\nphase breakdown (mean across 4 workers): {}", breakdown.report());
    Ok(())
}

//! End-to-end driver (the repo's headline validation run): the paper's
//! Fig 9 composite workload `join → groupby → sort → add_scalar` executed
//! on a real (generated, paper-spec) dataset across **all three systems**
//! — CylonFlow (pseudo-BSP actors), the AMT baseline (Dask-DDF analogue)
//! and the actor-MR baseline (Spark analogue) — plus the serial columnar
//! and row-oriented references, reporting wall times and the headline
//! speedup. Results are recorded in EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example etl_pipeline -- [rows] [workers]
//! ```

use cylonflow::actor_mr::MrRuntime;
use cylonflow::amt::{AmtDataFrame, AmtRuntime, TaskGraph};
use cylonflow::ops::{self, AggFun, AggSpec, JoinOptions, SortOptions};
use cylonflow::prelude::*;
use cylonflow::table::Table;
use std::time::Instant;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let rows: usize = argv.first().and_then(|v| v.parse().ok()).unwrap_or(2_000_000);
    let p: usize = argv.get(1).and_then(|v| v.parse().ok()).unwrap_or(8);
    let card = 0.9; // the paper's worst-case cardinality
    println!("ETL pipeline: join → groupby → sort → add_scalar");
    println!("rows={rows} x2 tables, cardinality={card}, parallelism={p}\n");

    // Workers generate their partitions (stands in for Parquet loads).
    let lparts: Vec<Table> = (0..p)
        .map(|r| datagen::partition_for_rank(101, rows, card, r, p))
        .collect();
    let rparts: Vec<Table> = (0..p)
        .map(|r| datagen::partition_for_rank(102, rows, card, r, p))
        .collect();

    // ---- CylonFlow (stateful pseudo-BSP actors) ------------------------
    let cluster = Cluster::local(p)?;
    let exec = CylonExecutor::new(&cluster, p)?;
    let t0 = Instant::now();
    let (outs, breakdown) = exec
        .run(move |env| {
            let l = datagen::partition_for_rank(101, rows, card, env.rank(), env.world_size());
            let r = datagen::partition_for_rank(102, rows, card, env.rank(), env.world_size());
            env.barrier()?; // exclude generation skew from the timing
            dist::pipeline(l, r, 42.0, env)
        })?
        .wait_with_metrics()?;
    let cf_time = t0.elapsed().as_secs_f64();
    let out_rows: usize = outs.iter().map(|o| o.table.num_rows()).sum();
    println!("cylonflow      : {cf_time:>8.3}s   ({out_rows} output rows)");
    println!("                 {}", breakdown.report());

    // ---- actor-MR baseline (Spark analogue) ----------------------------
    let mr = MrRuntime::new(p);
    let t0 = Instant::now();
    let mr_out = mr.pipeline(&lparts, &rparts, 42.0)?;
    let mr_time = t0.elapsed().as_secs_f64();
    println!(
        "actor-mr       : {mr_time:>8.3}s   ({} output rows)",
        mr_out.iter().map(|t| t.num_rows()).sum::<usize>()
    );

    // ---- AMT baseline (Dask-DDF analogue) ------------------------------
    let amt = AmtRuntime::new(p);
    let mut g = TaskGraph::new();
    let ldf = AmtDataFrame::from_partitions(&mut g, lparts.clone());
    let rdf = AmtDataFrame::from_partitions(&mut g, rparts.clone());
    let j = ldf.join(&mut g, &rdf, &JoinOptions::inner(0, 0));
    let gb = j.groupby(
        &mut g,
        vec![0],
        vec![AggSpec::new(1, AggFun::Sum), AggSpec::new(3, AggFun::Sum)],
    );
    let s = gb.sort(&mut g, &SortOptions::by(0));
    let fin = s.add_scalar(&mut g, 1, 42.0);
    let t0 = Instant::now();
    let amt_out = amt.execute(g, fin.deps())?;
    let amt_time = t0.elapsed().as_secs_f64();
    println!(
        "amt (dask-ish) : {amt_time:>8.3}s   ({} output rows)",
        amt_out.iter().map(|t| t.num_rows()).sum::<usize>()
    );

    // ---- serial references ---------------------------------------------
    let lall = Table::concat_owned(lparts)?;
    let rall = Table::concat_owned(rparts)?;
    let t0 = Instant::now();
    let j = ops::join(&lall, &rall, &JoinOptions::inner(0, 0))?;
    let gb = ops::groupby(
        &j,
        &[0],
        &[AggSpec::new(1, AggFun::Sum), AggSpec::new(3, AggFun::Sum)],
    )?;
    let s = ops::sort(&gb, &SortOptions::by(0))?;
    let _ = ops::add_scalar(&s, 1, 42.0)?;
    let serial_time = t0.elapsed().as_secs_f64();
    println!("serial columnar: {serial_time:>8.3}s");

    // row-oriented baseline only at small sizes (it is *slow*)
    if rows <= 500_000 {
        let t0 = Instant::now();
        let _ = cylonflow::baseline_naive::pipeline_rows(&lall, &rall, 42)?;
        println!("serial row-wise: {:>8.3}s", t0.elapsed().as_secs_f64());
    }

    println!(
        "\nheadline: cylonflow {:.1}x faster than AMT, {:.1}x faster than actor-MR, \
         {:.1}x speedup over serial (p={p})",
        amt_time / cf_time,
        mr_time / cf_time,
        serial_time / cf_time
    );
    Ok(())
}

//! The paper's §IV-C scenario: two applications on disjoint resource
//! partitions of one cluster, sharing a DDF through the CylonStore —
//! a preprocessing app (parallelism 4) feeds a downstream "training data
//! assembly" app (parallelism 2); the store repartitions between them.
//!
//! ```bash
//! cargo run --release --example multi_app
//! ```

use cylonflow::prelude::*;
use std::time::Duration;

fn main() -> Result<()> {
    // One cluster, 6 workers — the two apps gang-reserve 4 + 2.
    let cluster = Cluster::local(6)?;

    // --- application 1: auxiliary data preprocessing (p=4) -------------
    let preprocess = CylonExecutor::new(&cluster, 4)?;
    println!(
        "cluster: {} workers; preprocessing app reserved 4 ({} free)",
        cluster.num_workers(),
        cluster.available_workers()
    );
    let pre_handle = preprocess.run(|env| {
        // clean + aggregate an auxiliary table, publish it
        let raw = datagen::partition_for_rank(7, 400_000, 0.5, env.rank(), env.world_size());
        let agg = dist::groupby(
            &raw,
            &[0],
            &[AggSpec::new(1, dist::AggFun::Mean)],
            dist::GroupbyStrategy::TwoPhase,
            env,
        )?;
        env.store().put("aux_data", agg.clone())?;
        Ok(agg.num_rows())
    })?;

    // --- application 2: main assembly (p=2), starts concurrently -------
    let main_app = CylonExecutor::new(&cluster, 2)?;
    println!("main app reserved 2 ({} free)", cluster.available_workers());
    let main_handle = main_app.run(|env| {
        let data = datagen::partition_for_rank(8, 200_000, 0.9, env.rank(), env.world_size());
        // blocks until the producer publishes; repartitions 4 -> 2
        let aux = env.store().get("aux_data", Duration::from_secs(30))?;
        let df = dist::join(&data, &aux, &JoinOptions::inner(0, 0), env)?;
        // (in the paper's example this feeds torch.from_numpy(...))
        Ok((aux.num_rows(), df.num_rows()))
    })?;

    let pre_rows: usize = pre_handle.wait()?.iter().sum();
    let main_out = main_handle.wait()?;
    let aux_rows: usize = main_out.iter().map(|(a, _)| a).sum();
    let joined: usize = main_out.iter().map(|(_, j)| j).sum();
    println!("\npreprocessing produced {pre_rows} aggregated rows (4 partitions)");
    println!("main app consumed {aux_rows} rows after 4→2 repartition");
    println!("joined training table: {joined} rows");
    assert_eq!(pre_rows, aux_rows, "store must hand over every row");
    println!("\nmulti-app store handoff OK");
    // Exit report: one unified metrics line per application gang.
    for (name, app) in [("preprocess", &preprocess), ("main_app", &main_app)] {
        if let Some(snap) = app.run(|env| Ok(env.snapshot()))?.wait()?.into_iter().next() {
            println!("{name}: {}", snap.summary());
        }
    }
    Ok(())
}
